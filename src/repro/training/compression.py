"""Gradient compression for the data-parallel all-reduce.

At 1000-node scale the DP gradient all-reduce is a dominant collective; this
module provides int8 quantize -> psum -> dequantize under ``shard_map``, with
per-tensor scales and stochastic rounding (unbiased: E[q] = g). Used by the
manual-DP train step variant (``train.make_compressed_dp_step``) and
benchmarked against the uncompressed path in the tests.

Bandwidth: 4x reduction vs f32 grads (2x vs bf16) at the cost of one extra
scalar all-reduce for the scale max.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum"]


def quantize_int8(g: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stochastic-rounding int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-20
    x = gf / scale
    lo = jnp.floor(x)
    frac = x - lo
    rnd = jax.random.uniform(key, x.shape)
    q = lo + (rnd < frac).astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(mesh: Mesh, dp_axes: tuple[str, ...]):
    """Returns f(grads_tree, key) -> mean-reduced grads over dp_axes with int8
    on-the-wire representation. Call under shard_map or wrap standalone."""

    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]

    def local_reduce(grads, key):
        # inside shard_map: quantize local grads, psum int32 (int8 payload
        # widened for accumulation), dequant with psum'd max-scale.
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(key, len(leaves))
        out = []
        for g, k in zip(leaves, keys):
            q, scale = quantize_int8(g, k)
            scale = jax.lax.pmax(scale, dp_axes)  # shared scale (max is safe)
            q32 = jax.lax.psum(q.astype(jnp.int32), dp_axes)
            out.append((q32.astype(jnp.float32) * scale / n).astype(g.dtype))
        return treedef.unflatten(out)

    def fn(grads, key):
        specs = jax.tree.map(lambda _: P(), grads)  # grads replicated per-shard view
        return shard_map(
            local_reduce,
            mesh=mesh,
            in_specs=(specs, P()),
            out_specs=specs,
            check_rep=False,
        )(grads, key)

    return fn
