"""AdamW in pure JAX (no optax dependency) with schedules and global clipping.

Moments are float32 regardless of parameter dtype; the update path is the
standard decoupled-weight-decay Adam. Optimizer state shards exactly like the
parameters (the planner maps specs leaf-for-leaf), giving ZeRO-style
partitioning for free under GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: OptConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay only on matrices (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
