"""Serving steps: prefill and batched decode over the model zoo.

``make_prefill_step`` / ``make_decode_step`` return jittable closures;
``generate`` runs a host-side batched greedy/sampling loop (used by the
serving example and the correctness test that cross-checks incremental decode
against a full forward pass).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.zoo import Model

__all__ = ["make_prefill_step", "make_decode_step", "generate"]


def make_prefill_step(model: Model, plan=None):
    ctx = plan.ctx() if plan is not None else None

    def prefill_step(params, batch):
        return model.prefill(params, ctx, batch)

    return jax.jit(prefill_step)


def make_decode_step(model: Model, plan=None):
    ctx = plan.ctx() if plan is not None else None

    def decode_step(params, batch, cache):
        return model.decode(params, ctx, batch, cache)

    return jax.jit(decode_step)


def generate(
    model: Model,
    params,
    prompt_tokens: np.ndarray,
    max_new: int = 16,
    temperature: float = 0.0,
    seed: int = 0,
    extra: dict | None = None,
):
    """Greedy/temperature sampling. prompt_tokens: (B, S). Returns (B, max_new)."""
    b, s = prompt_tokens.shape
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    batch: dict[str, Any] = {"tokens": jnp.asarray(prompt_tokens, jnp.int32)}
    if extra:
        batch.update(extra)
    logits, cache = prefill(params, batch)
    # grow caches so decode has room: pad attention caches to s + max_new
    cache = _grow_cache(cache, s, s + max_new)
    key = jax.random.PRNGKey(seed)
    out = []
    pos = s
    last = logits[:, -1]
    for i in range(max_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            tok = jnp.argmax(last, axis=-1)
        out.append(np.asarray(tok))
        dec_batch = {
            "tokens": tok[:, None].astype(jnp.int32),
            "positions": jnp.full((b,), pos, jnp.int32),
        }
        logits, cache = decode(params, dec_batch, cache)
        last = logits[:, 0]
        pos += 1
    return np.stack(out, axis=1)


def _grow_cache(cache, cur_len: int, new_len: int):
    """Pad sequence dim of attention caches from cur_len to new_len."""
    if new_len <= cur_len:
        return cache

    def grow(path, leaf):
        name = None
        for k in path:
            if hasattr(k, "key"):
                name = str(k.key)
        if name in ("k", "v", "c_kv", "k_rope"):
            # sequence dim: (…, B, L, …) — find the dim equal to cur_len
            shape = list(leaf.shape)
            for d, sz in enumerate(shape):
                if sz == cur_len:
                    pad = [(0, 0)] * len(shape)
                    pad[d] = (0, new_len - cur_len)
                    return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, cache)
