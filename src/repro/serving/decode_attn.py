"""Sequence-sharded decode attention (flash-decoding on the mesh).

For long-context decode (``long_500k``, batch 1) neither batch nor (often)
KV heads offer enough parallelism, and a single device cannot hold the KV
cache. This splits the cache *sequence* across a mesh axis: every shard
computes attention over its local KV slice with a local log-sum-exp, then the
shards combine numerically exactly:

    m   = pmax(m_local)
    num = psum(exp(m_local - m) * acc_local)
    den = psum(exp(m_local - m) * l_local)
    out = num / den

Two small collectives of size O(B·H·hd) replace any KV movement — the cache
never crosses the interconnect. Exactness (== single-device
``decode_attention``) is validated in ``tests/test_decode_attn.py`` on an
8-device host mesh; gemma3's global layers use this path for ``long_500k``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["seq_sharded_decode_attention"]

_NEG = -1e30


def _local_part(q, k_shard, v_shard, start, lengths, window):
    """Partial attention over a KV slice. Returns (acc, l, m) un-normalised."""
    b, _, h, hd = q.shape
    Ls, n_kv = k_shard.shape[1], k_shard.shape[2]
    g = h // n_kv
    scale = hd ** -0.5
    qg = q.reshape(b, n_kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,blkd->bkgl", qg, k_shard.astype(jnp.float32)) * scale
    pos = start + jnp.arange(Ls)[None, :]  # absolute cache positions
    valid = pos < lengths[:, None]
    if window:
        valid = valid & (pos >= lengths[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    m = s.max(axis=-1)  # (b, kv, g)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)  # kill exp(_NEG - _NEG)
    acc = jnp.einsum("bkgl,blkd->bkgd", p, v_shard.astype(jnp.float32))
    l = p.sum(axis=-1)
    return acc, l, m


def seq_sharded_decode_attention(
    mesh: Mesh,
    *,
    seq_axis: str = "data",
    window: int = 0,
):
    """Build f(q, k_cache, v_cache, lengths) with the cache sequence dim
    sharded over ``seq_axis``. q: (B,1,H,hd) replicated over seq_axis;
    k/v_cache: (B, L, KV, hd) sharded on dim 1; lengths: (B,)."""
    n_shards = mesh.shape[seq_axis]

    def local(q, k_shard, v_shard, lengths):
        b, one, h, hd = q.shape
        Ls = k_shard.shape[1]
        start = jax.lax.axis_index(seq_axis) * Ls
        acc, l, m = _local_part(q, k_shard, v_shard, start, lengths, window)
        m_glob = jax.lax.pmax(m, seq_axis)
        w = jnp.exp(m - m_glob)
        num = jax.lax.psum(acc * w[..., None], seq_axis)
        den = jax.lax.psum(l * w, seq_axis)
        out = num / jnp.maximum(den[..., None], 1e-37)
        return out.reshape(b, 1, h, hd).astype(q.dtype)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(None, seq_axis, None, None), P(None, seq_axis, None, None), P()),
        out_specs=P(),
        check_rep=False,
    )
