"""MINIT baseline (Haglin & Manning 2007) — the paper's main comparison point.

MINIT mines minimal τ-infrequent itemsets by recursive depth-first search:
items are ranked by support ascending; for each item ``a`` the dataset is
*conditioned* on ``R_a`` and the search recurses over higher-ranked items
only. Candidate outputs are verified minimal with a support-set test.

Implementation notes (faithful to the published algorithm's structure, with
the standard pruning rules):
  * items with zero support in the conditional dataset are dropped;
  * items *uniform* in the conditional dataset cannot extend a minimal
    infrequent set (same argument as paper §4.1) and are dropped;
  * recursion depth is capped at ``k_max``;
  * minimality of an emitted set is verified against all (|I|-1)-subsets.

This is a host (numpy bitset) implementation — the baseline the paper itself
benchmarks against is a sequential CPU code, so a host baseline is the honest
comparison target for wall-clock benches.
"""

from __future__ import annotations

import numpy as np

from .bitops import popcount
from .items import itemize

__all__ = ["minit_minimal_infrequent"]


def minit_minimal_infrequent(dataset: np.ndarray, tau: int, kmax: int) -> set[tuple[int, ...]]:
    table = itemize(dataset)
    n = table.n_rows
    bits = table.bits
    freq = table.freq.astype(np.int64)

    full_mask = np.full(table.n_words, 0xFFFFFFFF, dtype=np.uint32)
    tail = n % 32
    if tail:
        full_mask[-1] = np.uint32((1 << tail) - 1)

    # drop globally-uniform items (cannot be in any minimal infrequent set)
    candidates = [i for i in range(table.n_items) if freq[i] < n]
    # rank ascending by support (MINIT ordering)
    candidates.sort(key=lambda i: (freq[i], table.col[i], table.min_row[i]))

    results: set[tuple[int, ...]] = set()

    def set_freq(itemset: tuple[int, ...]) -> int:
        m = full_mask
        for it in itemset:
            m = m & bits[it]
        return int(popcount(m).sum())

    def is_minimal(itemset: tuple[int, ...]) -> bool:
        if len(itemset) == 1:
            return True
        for drop in range(len(itemset)):
            sub = itemset[:drop] + itemset[drop + 1 :]
            if set_freq(sub) <= tau:
                return False
        return True

    def recurse(chosen: tuple[int, ...], row_mask: np.ndarray, items: list[int]) -> None:
        depth = len(chosen)
        if depth >= kmax:
            return
        # local supports in the conditional dataset
        local = []
        rows_in_mask = int(popcount(row_mask).sum())
        for it in items:
            inter = row_mask & bits[it]
            c = int(popcount(inter).sum())
            if c == 0:
                continue  # absent in conditional dataset
            if c == rows_in_mask and depth > 0:
                continue  # uniform in conditional dataset -> non-minimal ext.
            local.append((c, it, inter))
        local.sort(key=lambda x: x[0])
        for rank, (c, it, inter) in enumerate(local):
            cand = tuple(sorted(chosen + (it,)))
            if c <= tau:
                if is_minimal(cand):
                    results.add(cand)
            else:
                recurse(
                    cand,
                    inter,
                    [x[1] for x in local[rank + 1 :]],
                )

    recurse((), full_mask, candidates)
    return results
