"""Level representation and breadth-first candidate generation (Alg. 1 lines 11-20).

A BFS level ``k`` is a lexicographically sorted ``(t, k)`` int32 table of
itemsets (entries are *positions* into the ordered list ``L^<``, so that
lexicographic order on positions equals prefix-tree order), together with the
``(t,)`` frequencies and the ``(t, W)`` uint32 bitset matrix of row sets.

Candidates at level ``k+1`` join two level-``k`` itemsets that share their
first ``k-1`` items (a prefix group). Pair enumeration is fully vectorised:
within a contiguous group of size ``c`` every row pairs with each of its
followers, which is expressed with ``repeat``/``cumsum`` arithmetic — no
Python-level loop over pairs or groups.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Level",
    "CandidateBatch",
    "generate_candidates",
    "prefix_group_sizes",
    "group_reps",
    "iter_group_spans",
]


@dataclasses.dataclass
class Level:
    """Stored BFS level (the paper's ``{P_i}``)."""

    k: int
    itemsets: np.ndarray  # (t, k) int32, lexicographically sorted rows
    counts: np.ndarray  # (t,) int64 frequencies |R_I|
    bits: np.ndarray | None  # (t, W) uint32; None once a level is retired

    @property
    def t(self) -> int:
        return int(self.itemsets.shape[0])


@dataclasses.dataclass
class CandidateBatch:
    """All candidate joins for one level transition.

    ``i_idx``/``j_idx`` index rows of the parent level; the candidate itemset
    is ``parent.itemsets[i] ∪ {last item of parent.itemsets[j]}`` which, with
    shared prefixes and lexicographic storage, is simply the concatenation
    ``[prefix..., last_i, last_j]`` and is itself lexicographically ordered.
    """

    i_idx: np.ndarray  # (M,) int64
    j_idx: np.ndarray  # (M,) int64
    itemsets: np.ndarray  # (M, k+1) int32

    @property
    def m(self) -> int:
        return int(self.i_idx.shape[0])


def prefix_group_sizes(itemsets: np.ndarray) -> np.ndarray:
    """Sizes of contiguous groups sharing the first k-1 columns."""
    t, k = itemsets.shape
    if t == 0:
        return np.zeros(0, dtype=np.int64)
    if k == 1:
        return np.asarray([t], dtype=np.int64)
    neq = np.any(itemsets[1:, : k - 1] != itemsets[:-1, : k - 1], axis=1)
    group_id = np.concatenate([[0], np.cumsum(neq)])
    return np.bincount(group_id).astype(np.int64)


def group_reps(itemsets: np.ndarray) -> np.ndarray:
    """Per-row join run lengths: row ``r`` (local index ``l`` in a prefix
    group of size ``c``) is the *I* of ``c - 1 - l`` candidate pairs. These
    run lengths are the input of both the host ``repeat``/``cumsum``
    enumeration and the device frontier's ``cumsum``/``searchsorted`` one."""
    t = itemsets.shape[0]
    sizes = prefix_group_sizes(itemsets)
    starts = np.zeros(len(sizes), dtype=np.int64)
    starts[1:] = np.cumsum(sizes)[:-1]
    group_id = np.repeat(np.arange(len(sizes)), sizes)
    local = np.arange(t, dtype=np.int64) - starts[group_id]
    return sizes[group_id] - 1 - local


def iter_group_spans(sizes: np.ndarray, max_pairs: int):
    """Yield ``(row_lo, row_hi, n_pairs)`` batch spans (paper §6.1 level
    streaming): consecutive prefix groups are packed until the pair budget
    is reached, so candidate tables never materialise a whole level's join
    at once. A single group larger than the budget is emitted alone (pairs
    cannot cross groups). Both the host path and the device frontier batch
    over the same spans, which is what keeps their per-level stats
    bit-identical."""
    pair_counts = sizes * (sizes - 1) // 2
    starts = np.zeros(len(sizes), dtype=np.int64)
    starts[1:] = np.cumsum(sizes)[:-1]
    g = 0
    while g < len(sizes):
        acc = 0
        g_end = g
        while g_end < len(sizes) and (acc == 0 or acc + pair_counts[g_end] <= max_pairs):
            acc += pair_counts[g_end]
            g_end += 1
        row_lo = int(starts[g])
        row_hi = int(starts[g_end - 1] + sizes[g_end - 1]) if g_end > g else row_lo
        yield row_lo, row_hi, int(acc)
        g = g_end


def iter_candidate_batches(level: Level, max_pairs: int):
    """Yield CandidateBatch objects bounded by ~max_pairs (see
    :func:`iter_group_spans` for the batching plan)."""
    t, k = level.itemsets.shape
    if t < 2:
        return
    sizes = prefix_group_sizes(level.itemsets)
    for row_lo, row_hi, n_pairs in iter_group_spans(sizes, max_pairs):
        if n_pairs == 0:
            continue
        sub = Level(
            k=level.k,
            itemsets=level.itemsets[row_lo:row_hi],
            counts=level.counts[row_lo:row_hi],
            bits=None,
        )
        batch = generate_candidates(sub)
        if batch.m:
            yield CandidateBatch(
                i_idx=batch.i_idx + row_lo,
                j_idx=batch.j_idx + row_lo,
                itemsets=batch.itemsets,
            )


def generate_candidates(level: Level) -> CandidateBatch:
    """Enumerate all (I, J) joins of a level (Alg. 1 lines 11-20), vectorised."""
    t, k = level.itemsets.shape
    empty = CandidateBatch(
        i_idx=np.zeros(0, dtype=np.int64),
        j_idx=np.zeros(0, dtype=np.int64),
        itemsets=np.zeros((0, k + 1), dtype=np.int32),
    )
    if t < 2:
        return empty

    reps = group_reps(level.itemsets)
    total = int(reps.sum())
    if total == 0:
        return empty
    i_idx = np.repeat(np.arange(t, dtype=np.int64), reps)
    offsets = np.zeros(t, dtype=np.int64)
    offsets[1:] = np.cumsum(reps)[:-1]
    j_idx = np.arange(total, dtype=np.int64) - np.repeat(offsets, reps) + i_idx + 1

    itemsets = np.empty((total, k + 1), dtype=np.int32)
    itemsets[:, :k] = level.itemsets[i_idx]
    itemsets[:, k] = level.itemsets[j_idx, k - 1]
    return CandidateBatch(i_idx=i_idx, j_idx=j_idx, itemsets=itemsets)
