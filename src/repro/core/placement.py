"""Bitset placement layer: ONE abstraction for where level bitsets live.

Before this module existed, bitset placement was hard-coded three different
ways: ``kernels.intersect.ops.LevelPipeline`` branched on engine strings and
assumed a single device, ``core.sharded`` carried its own device-put /
pair-bucketing plumbing, and ``service.store`` pinned every version to a
single-device cache.  A :class:`BitsetPlacement` now answers the four
questions every consumer was answering ad hoc:

1. **residency** — how do a level's parent bitsets (and popcounts) become
   resident for the duration of a BFS level (:meth:`~BitsetPlacement.prepare`),
   and how does a long-lived array (the service's ``DatasetStore``) get
   placed once per version (:meth:`~BitsetPlacement.put_bits`);
2. **padding** — what batch sizes keep executables reused
   (:meth:`~BitsetPlacement.padded_size`): power-of-two buckets on a single
   device, additionally rounded to equal per-shard blocks on a mesh;
3. **dispatch** — how one padded pair batch executes
   (:meth:`~BitsetPlacement.dispatch`): host numpy, single-device jnp/pallas
   kernels, or a ``shard_map`` body with a word-axis popcount ``psum``;
4. **layout** — what word-tile multiple keeps stored bitsets placeable with
   zero re-packing (:attr:`~BitsetPlacement.store_word_tile`).

The same four answers serve two workloads: the mining level batches
(:meth:`~BitsetPlacement.prepare` / :meth:`~BitsetPlacement.dispatch`,
orchestrated by ``kernels.intersect.ops.LevelPipeline``) and the privacy
risk engine's record-coverage queries
(:meth:`~BitsetPlacement.prepare_coverage` /
:meth:`~BitsetPlacement.coverage_dispatch`, orchestrated by
``kernels.coverage.ops.CoverageEngine``) — itemset-level and record-level
questions over the same resident bitsets.

The generic batch orchestration (locality sort, async handles, padding
strips, inverse permutation) lives once in
``kernels.intersect.ops.LevelPipeline``, which takes a placement instead of
branching on engine strings.  All placements are bit-identical on mining
results and per-level counters (property-tested in ``tests/test_placement.py``
and the 8-device drivers in ``tests/test_sharded_driver.py`` /
``tests/test_mesh_service.py``).

Implementations
---------------

* :class:`HostPlacement` — numpy on the host; no padding, eager dispatch.
* :class:`DevicePlacement` — one JAX device (``jnp`` oracle under jit or the
  Pallas kernels); parent bitsets uploaded once per level, executables bound
  per power-of-two bucket through the process-wide ``EXEC_CACHE``.
* :class:`MeshPlacement` — SPMD mesh: candidate pairs shard over the
  ``data`` (+``pod``) axes, bitset **words** shard over the ``model`` axis
  (row-parallelism for datasets whose bitset rows exceed one device), and
  per-shard partial popcounts are ``psum``-ed — the only collective in the
  level body, mirroring the paper's "no inter-thread communication"
  property (§4.4.4).

``make_placement`` / ``resolve_placement`` are the one factory the driver,
the service and the launchers all go through.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.intersect import ops as _ops
from .bitops import popcount_rows

__all__ = [
    "BitsetPlacement",
    "HostPlacement",
    "DevicePlacement",
    "MeshPlacement",
    "make_placement",
    "resolve_placement",
]


@runtime_checkable
class BitsetPlacement(Protocol):
    """Where bitsets live and how an intersect+classify batch executes.

    ``kind`` names the placement ("host" / "device" / "mesh");
    ``store_word_tile`` is the word-count multiple stored bitset matrices
    must be padded to so :meth:`put_bits` never re-packs (1 for host and
    single-device, the word-shard count on a mesh).
    """

    kind: str
    store_word_tile: int

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool) -> Any:
        """Make one level's parent bitsets + popcounts resident; returns an
        opaque state consumed by :meth:`dispatch` for every batch of the
        level."""
        ...

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        """Batch size ``m`` padded to this placement's executable bucket."""
        ...

    def dispatch(self, state: Any, padded_pairs: np.ndarray, write_children: bool):
        """Execute one padded batch; returns ``(child | None, counts,
        classes | None)`` as placement-native arrays (numpy or device;
        ``LevelPipeline`` materializes and strips padding)."""
        ...

    def put_bits(self, bits: np.ndarray):
        """Place a long-lived bitset matrix (the dataset store's cache)."""
        ...

    def prepare_coverage(self, bits):
        """Make an item bitset matrix resident for record-coverage queries
        (the privacy risk engine); returns an opaque state consumed by
        :meth:`coverage_dispatch` for every itemset batch."""
        ...

    def coverage_dispatch(self, state, padded_sets: np.ndarray, padded_weights: np.ndarray):
        """Execute one padded coverage batch (``kernels.coverage``):
        returns the ``(32, W)`` int32 accumulator as a placement-native
        array. Batch padding rows carry weight 0."""
        ...

    def describe(self) -> dict:
        """Human/JSON-friendly placement info for ``/stats``."""
        ...


class HostPlacement:
    """Bitsets stay in host numpy; dispatch is eager and unpadded."""

    kind = "host"
    store_word_tile = 1

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        return (
            np.asarray(bits),
            np.asarray(parent_counts, dtype=np.int64),
            int(tau),
            fused_classify,
        )

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        return m  # host gathers have no executable buckets to reuse

    def dispatch(self, state, padded_pairs: np.ndarray, write_children: bool):
        bits, pc, tau, fused = state
        a = bits[padded_pairs[:, 0]]
        b = bits[padded_pairs[:, 1]]
        child = np.bitwise_and(a, b)
        counts = popcount_rows(child)
        classes = None
        if fused:
            minp = np.minimum(pc[padded_pairs[:, 0]], pc[padded_pairs[:, 1]])
            classes = _ops.classify_counts_host(counts, minp, tau)
        return (child if write_children else None), counts, classes

    def put_bits(self, bits: np.ndarray):
        return np.ascontiguousarray(bits)

    def prepare_coverage(self, bits):
        return np.ascontiguousarray(np.asarray(bits, dtype=np.uint32))

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        from ..kernels.coverage.ref import coverage_accumulate_host

        return coverage_accumulate_host(state, padded_sets, padded_weights)

    def describe(self) -> dict:
        return {"kind": self.kind, "engine": "numpy", "devices": 0}

    def __repr__(self) -> str:
        return "HostPlacement()"


class DevicePlacement:
    """One JAX device: the jnp oracle under jit or the Pallas kernels.

    Parent bitsets and popcounts upload once per level; every batch ships
    only the (tiny) padded pair list, and the bound dispatch callable is
    shared process-wide per bucket shape through ``ops.EXEC_CACHE``.
    """

    kind = "device"
    store_word_tile = 1

    def __init__(
        self,
        engine: str = "jnp",
        *,
        interpret: bool = True,
        indexed: bool = True,
        block_pairs: int = 8,
        block_words: int = 512,
    ):
        if engine not in ("jnp", "pallas"):
            raise ValueError(f"DevicePlacement engine must be jnp|pallas, got {engine!r}")
        self.engine = engine
        self.interpret = interpret
        self.indexed = indexed
        self.block_pairs = block_pairs
        self.block_words = block_words
        # gathered write path: donate the gathered operand on accelerator
        # backends so the child output aliases its buffer; CPU donation is
        # unsupported (warning + copy), so gate on backend.
        self.donate = jax.default_backend() in ("tpu", "gpu")

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        return (
            jnp.asarray(bits),
            jnp.asarray(np.asarray(parent_counts), dtype=jnp.int32),
            jnp.int32(int(tau)),
            int(bits.shape[1]),
            fused_classify,
        )

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        return _ops.next_bucket(m) if pad_buckets else m

    def dispatch(self, state, padded_pairs: np.ndarray, write_children: bool):
        bits, pc, tau, n_words, fused = state
        bucket = int(padded_pairs.shape[0])
        key = (
            self.engine,
            self.indexed,
            fused,
            write_children,
            n_words,
            bucket,
            self.block_pairs,
            self.block_words,
            self.interpret,
            self.donate,
        )
        fn = _ops.EXEC_CACHE.get(
            key,
            lambda: _ops.build_engine_dispatch(
                self.engine,
                indexed=self.indexed,
                fused_classify=fused,
                write_children=write_children,
                n_words=n_words,
                bucket=bucket,
                block_pairs=self.block_pairs,
                block_words=self.block_words,
                interpret=self.interpret,
                donate=self.donate,
            ),
        )
        return fn(bits, jnp.asarray(padded_pairs), pc, tau)

    def put_bits(self, bits: np.ndarray):
        return jnp.asarray(bits)

    def prepare_coverage(self, bits):
        return jnp.asarray(bits)

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        from ..kernels.coverage import ops as _cov

        n_words = int(state.shape[1])
        bucket, width = int(padded_sets.shape[0]), int(padded_sets.shape[1])
        key = (
            "coverage",
            self.engine,
            width,
            n_words,
            bucket,
            self.block_words,
            self.interpret,
        )
        fn = _cov.EXEC_CACHE.get(
            key,
            lambda: _cov.build_coverage_dispatch(
                self.engine,
                n_words=n_words,
                block_words=self.block_words,
                interpret=self.interpret,
            ),
        )
        return fn(state, jnp.asarray(padded_sets), jnp.asarray(padded_weights))

    def describe(self) -> dict:
        return {
            "kind": self.kind,
            "engine": self.engine,
            "devices": 1,
            "backend": jax.default_backend(),
            "indexed": self.indexed,
            "interpret": self.interpret,
        }

    def __repr__(self) -> str:
        return f"DevicePlacement(engine={self.engine!r})"


class MeshPlacement:
    """SPMD mesh: pairs shard over ``pair_axes``, words over ``word_axis``.

    The level body is a ``shard_map`` whose only collective is the word-axis
    popcount ``psum`` (classification happens after it, per pair shard, with
    zero extra communication).  Stored bitset matrices placed through
    :meth:`put_bits` must have a word count that is a multiple of
    :attr:`store_word_tile` (= the word-shard count) — the ``DatasetStore``
    aligns its tile to this, so serving a mesh never re-packs bits.
    """

    kind = "mesh"

    def __init__(
        self,
        mesh: Mesh,
        *,
        pair_axes: tuple[str, ...] = ("data",),
        word_axis: str | None = None,
    ):
        self.mesh = mesh
        self.pair_axes = tuple(pair_axes)
        self.word_axis = word_axis
        self.pair_shards = int(np.prod([mesh.shape[a] for a in self.pair_axes]))
        self.word_shards = int(mesh.shape[word_axis]) if word_axis else 1
        self.store_word_tile = self.word_shards
        self._bits_sharding = NamedSharding(mesh, P(None, word_axis))
        self._pairs_sharding = NamedSharding(mesh, P(self.pair_axes, None))
        self._minp_sharding = NamedSharding(mesh, P(self.pair_axes))

    # the jitted shard_map bodies are bound once per (mesh, axes, variant)
    # through EXEC_CACHE, so executables are shared across levels, placements
    # of the same mesh, and mining requests (warm-start on the service).
    def _step_fn(self, fused: bool, write_children: bool):
        from . import sharded as _sh

        key = ("mesh", self.mesh, self.pair_axes, self.word_axis, fused, write_children)

        def build():
            if fused:
                builder = (
                    _sh.sharded_level_classify_step
                    if write_children
                    else _sh.sharded_level_classify_count_step
                )
            else:
                builder = (
                    _sh.sharded_level_step if write_children else _sh.sharded_level_count_step
                )
            fn, _, _ = builder(
                self.mesh, pair_axes=self.pair_axes, word_axis=self.word_axis
            )
            return fn

        return _ops.EXEC_CACHE.get(key, build)

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        return (
            self.put_bits(bits),
            np.asarray(parent_counts, dtype=np.int32),
            jnp.int32(int(tau)),
            fused_classify,
        )

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        from .balance import balanced_blocks

        bucket = _ops.next_bucket(m) if pad_buckets else m
        padded_m, _ = balanced_blocks(bucket, self.pair_shards)
        return padded_m

    def dispatch(self, state, padded_pairs: np.ndarray, write_children: bool):
        bits, pc, tau, fused = state
        pairs_j = jax.device_put(jnp.asarray(padded_pairs), self._pairs_sharding)
        if not fused:
            fn = self._step_fn(False, write_children)
            if write_children:
                child, cnt = fn(bits, pairs_j)
                return child, cnt, None
            return None, fn(bits, pairs_j), None
        # padding rows are (0, 0) self-pairs, so their minp is pc[0] and the
        # fused classifier marks them CLASS_SKIP (count == min parent count)
        minp = np.minimum(pc[padded_pairs[:, 0]], pc[padded_pairs[:, 1]])
        minp_j = jax.device_put(jnp.asarray(minp), self._minp_sharding)
        fn = self._step_fn(True, write_children)
        if write_children:
            return fn(bits, pairs_j, minp_j, tau)
        cnt, cls = fn(bits, pairs_j, minp_j, tau)
        return None, cnt, cls

    def put_bits(self, bits):
        """Word-shard a bitset matrix over the mesh.  Host arrays are padded
        to the shard multiple first (zero words = no rows); arrays already
        tile-aligned — the dataset store's layout — ship with zero re-packing
        copies, and jax arrays already on the mesh reshard in place."""
        if not isinstance(bits, jax.Array):
            from .sharded import pad_words

            bits = pad_words(np.ascontiguousarray(bits), self.word_shards)
        return jax.device_put(bits, self._bits_sharding)

    def prepare_coverage(self, bits):
        return self.put_bits(bits)

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        from ..kernels.coverage import ops as _cov
        from . import sharded as _sh

        width = int(padded_sets.shape[1])
        key = ("coverage-mesh", self.mesh, self.pair_axes, self.word_axis, width)
        fn = _cov.EXEC_CACHE.get(
            key,
            lambda: _sh.sharded_coverage_step(
                self.mesh,
                pair_axes=self.pair_axes,
                word_axis=self.word_axis,
                n_set_items=width,
            )[0],
        )
        sets_j = jax.device_put(jnp.asarray(padded_sets), self._pairs_sharding)
        wt_j = jax.device_put(jnp.asarray(padded_weights), self._minp_sharding)
        return fn(state, sets_j, wt_j)

    def describe(self) -> dict:
        return {
            "kind": self.kind,
            "devices": int(np.prod(list(self.mesh.shape.values()))),
            "mesh_shape": dict(self.mesh.shape),
            "pair_axes": list(self.pair_axes),
            "word_axis": self.word_axis,
            "pair_shards": self.pair_shards,
            "word_shards": self.word_shards,
        }

    def __repr__(self) -> str:
        return (
            f"MeshPlacement(shape={dict(self.mesh.shape)}, "
            f"pair_axes={self.pair_axes}, word_axis={self.word_axis!r})"
        )


def make_placement(
    engine: str,
    *,
    interpret: bool = True,
    indexed: bool = True,
    block_pairs: int = 8,
    block_words: int = 512,
) -> BitsetPlacement:
    """Placement for an engine name: ``numpy``/``host`` -> host,
    ``jnp``/``pallas`` -> single device."""
    if engine in ("numpy", "host"):
        return HostPlacement()
    if engine in ("jnp", "pallas"):
        return DevicePlacement(
            engine,
            interpret=interpret,
            indexed=indexed,
            block_pairs=block_pairs,
            block_words=block_words,
        )
    raise ValueError(
        f"no placement for engine {engine!r} (expected numpy|jnp|pallas; "
        "meshes are constructed explicitly via MeshPlacement)"
    )


def resolve_placement(config) -> BitsetPlacement:
    """The one factory between ``KyivConfig`` and a placement.

    ``config.placement`` wins when set (a :class:`BitsetPlacement` instance,
    or an engine-name string resolved through :func:`make_placement`);
    otherwise the legacy ``config.engine`` string selects host or
    single-device placement with the config's kernel knobs.
    """
    p = getattr(config, "placement", None)
    if p is not None and not isinstance(p, str):
        return p
    engine = p if isinstance(p, str) else config.engine
    return make_placement(
        engine,
        interpret=getattr(config, "interpret", True),
        indexed=getattr(config, "indexed_kernel", True),
    )
