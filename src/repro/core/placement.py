"""Bitset placement layer: ONE abstraction for where level bitsets live.

Before this module existed, bitset placement was hard-coded three different
ways: ``kernels.intersect.ops.LevelPipeline`` branched on engine strings and
assumed a single device, ``core.sharded`` carried its own device-put /
pair-bucketing plumbing, and ``service.store`` pinned every version to a
single-device cache.  A :class:`BitsetPlacement` now answers the four
questions every consumer was answering ad hoc:

1. **residency** — how do a level's parent bitsets (and popcounts) become
   resident for the duration of a BFS level (:meth:`~BitsetPlacement.prepare`),
   and how does a long-lived array (the service's ``DatasetStore``) get
   placed once per version (:meth:`~BitsetPlacement.put_bits`);
2. **padding** — what batch sizes keep executables reused
   (:meth:`~BitsetPlacement.padded_size`): power-of-two buckets on a single
   device, additionally rounded to equal per-shard blocks on a mesh;
3. **dispatch** — how one padded pair batch executes
   (:meth:`~BitsetPlacement.dispatch`): host numpy, single-device jnp/pallas
   kernels, or a ``shard_map`` body with a word-axis popcount ``psum``;
4. **layout** — what word-tile multiple keeps stored bitsets placeable with
   zero re-packing (:attr:`~BitsetPlacement.store_word_tile`).

The same four answers serve two workloads: the mining level batches
(:meth:`~BitsetPlacement.prepare` / :meth:`~BitsetPlacement.dispatch`,
orchestrated by ``kernels.intersect.ops.LevelPipeline``) and the privacy
risk engine's record-coverage queries
(:meth:`~BitsetPlacement.prepare_coverage` /
:meth:`~BitsetPlacement.coverage_dispatch`, orchestrated by
``kernels.coverage.ops.CoverageEngine``) — itemset-level and record-level
questions over the same resident bitsets.

The generic batch orchestration (locality sort, async handles, padding
strips, inverse permutation) lives once in
``kernels.intersect.ops.LevelPipeline``, which takes a placement instead of
branching on engine strings.  All placements are bit-identical on mining
results and per-level counters (property-tested in ``tests/test_placement.py``
and the 8-device drivers in ``tests/test_sharded_driver.py`` /
``tests/test_mesh_service.py``).

Implementations
---------------

* :class:`HostPlacement` — numpy on the host; no padding, eager dispatch.
* :class:`DevicePlacement` — one JAX device (``jnp`` oracle under jit or the
  Pallas kernels); parent bitsets uploaded once per level, executables bound
  per power-of-two bucket through the process-wide ``EXEC_CACHE``.
* :class:`MeshPlacement` — SPMD mesh: candidate pairs shard over the
  ``data`` (+``pod``) axes, bitset **words** shard over the ``model`` axis
  (row-parallelism for datasets whose bitset rows exceed one device), and
  per-shard partial popcounts are ``psum``-ed — the only collective in the
  level body, mirroring the paper's "no inter-thread communication"
  property (§4.4.4).

``make_placement`` / ``resolve_placement`` are the one factory the driver,
the service and the launchers all go through.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.intersect import ops as _ops
from ..obs import cost as _obs_cost
from ..obs import metrics as _om
from .bitops import popcount_rows

__all__ = [
    "BitsetPlacement",
    "HostPlacement",
    "DevicePlacement",
    "MeshPlacement",
    "is_device_failure",
    "make_placement",
    "resolve_placement",
    "set_fault_hook",
]

# -- fault seam --------------------------------------------------------------
#
# Device and mesh dispatch paths call ``_guard(site)`` immediately before
# executing on the accelerator. The hook is the one process-wide seam both
# the fault-injection harness (``repro.service.faults``) and ad-hoc chaos
# experiments use to simulate XLA OOMs / device loss without touching the
# kernels; production leaves it None (a single attribute read per batch).
# Host dispatch is deliberately unguarded — it is the degradation target and
# must stay failure-free.

_fault_hook = None


def set_fault_hook(hook):
    """Install ``hook(site: str)`` ahead of every device/mesh dispatch
    (sites: "dispatch", "frontier", "coverage"). Returns the previous hook
    so callers can restore it."""
    global _fault_hook
    prev, _fault_hook = _fault_hook, hook
    return prev


_DISPATCHES = _om.counter(
    "repro_placement_dispatch_total",
    "Placement-layer dispatches by seam and backend kind.",
    ("site", "kind"),
)


def _count_dispatch(site: str, kind: str) -> None:
    _DISPATCHES.inc(site=site, kind=kind)


def _guard(site: str, kind: str = "device") -> None:
    # metrics first: a dispatch that the fault hook kills still happened
    # (chaos runs want to see attempted-vs-degraded rates). Host dispatch
    # never routes through here — it must stay failure-free (see above) —
    # so HostPlacement methods call _count_dispatch directly.
    _count_dispatch(site, kind)
    _obs_cost.add(device_dispatches=1)
    if _fault_hook is not None:
        _fault_hook(site)


# Substrings that mark an exception as an accelerator-runtime failure (XLA
# OOM, device loss, transfer errors) rather than a programming error. The
# service's degradation path only retries/degrades on these.
_DEVICE_FAILURE_MARKERS = (
    "RESOURCE_EXHAUSTED",
    "out of memory",
    "OUT_OF_MEMORY",
    "DEVICE_LOST",
    "device lost",
    "FAILED_PRECONDITION: device",
    "DATA_LOSS",
)


def is_device_failure(exc: BaseException) -> bool:
    """Is ``exc`` a device/runtime failure worth retrying on, or degrading
    Device/Mesh -> Host placement for — as opposed to a bug that would fail
    identically on the host? Injected faults mark themselves with an
    ``is_device_failure`` attribute; real JAX runtime errors are classified
    by type name and message."""
    if getattr(exc, "is_device_failure", False):
        return True
    if type(exc).__name__ == "XlaRuntimeError":
        return True
    msg = str(exc)
    return any(marker in msg for marker in _DEVICE_FAILURE_MARKERS)


@runtime_checkable
class BitsetPlacement(Protocol):
    """Where bitsets live and how an intersect+classify batch executes.

    ``kind`` names the placement ("host" / "device" / "mesh");
    ``store_word_tile`` is the word-count multiple stored bitset matrices
    must be padded to so :meth:`put_bits` never re-packs (1 for host and
    single-device, the word-shard count on a mesh).
    """

    kind: str
    store_word_tile: int

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool) -> Any:
        """Make one level's parent bitsets + popcounts resident; returns an
        opaque state consumed by :meth:`dispatch` for every batch of the
        level."""
        ...

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        """Batch size ``m`` padded to this placement's executable bucket."""
        ...

    def warm_buckets(
        self, n_words: int, *, fused: bool, write_children: bool
    ) -> tuple[int, ...]:
        """Bucket sizes with an already-bound intersect executable for this
        placement signature at ``n_words`` words, ascending — empty when
        dispatch has no per-bucket executables (host eager, mesh
        shape-polymorphic). The sampling tier pads boundary recounts to
        these so refinement hits warm executables instead of minting new
        single-use buckets."""
        ...

    def dispatch(self, state: Any, padded_pairs: np.ndarray, write_children: bool):
        """Execute one padded batch; returns ``(child | None, counts,
        classes | None)`` as placement-native arrays (numpy or device;
        ``LevelPipeline`` materializes and strips padding)."""
        ...

    def put_bits(self, bits: np.ndarray):
        """Place a long-lived bitset matrix (the dataset store's cache)."""
        ...

    def prepare_coverage(self, bits):
        """Make an item bitset matrix resident for record-coverage queries
        (the privacy risk engine); returns an opaque state consumed by
        :meth:`coverage_dispatch` for every itemset batch."""
        ...

    def coverage_dispatch(self, state, padded_sets: np.ndarray, padded_weights: np.ndarray):
        """Execute one padded coverage batch (``kernels.coverage``):
        returns the ``(32, W)`` int32 accumulator as a placement-native
        array. Batch padding rows carry weight 0."""
        ...

    def prepare_frontier(self, itemsets: np.ndarray, counts: np.ndarray, n_symbols: int) -> Any:
        """Make one BFS level's *id table* resident for frontier ops
        (candidate generation + support tests). Host returns the exact
        ``ItemsetIndex`` of the reference path; device/mesh upload the
        padded id table and packed sorted parent key table."""
        ...

    def frontier_dispatch(self, state: Any, lo: int, hi: int, n_pairs: int):
        """Generate + support-test the candidate pairs of one prefix-group
        span. Host returns ``(CandidateBatch, ok)`` numpy (today's path);
        device/mesh return ``(pairs (bucket, 2), ok (bucket,))`` device
        arrays, padding rows marked not-ok."""
        ...

    def frontier_mask(self, state: Any, pairs, ok):
        """Neutralise pruned candidates (self-pairs -> CLASS_SKIP) without
        reordering; returns ``(pairs, n_ok)`` placement-native."""
        ...

    def frontier_partition(self, classes):
        """One compaction pass over fused class codes: returns ``(order,
        n_emit, n_store)`` placement-native, segments in candidate order."""
        ...

    def release(self, state: Any) -> None:
        """Eagerly drop device buffers a :meth:`prepare` /
        :meth:`prepare_frontier` state owns (level retirement) — buffers the
        caller handed in stay alive."""
        ...

    def describe(self) -> dict:
        """Human/JSON-friendly placement info for ``/stats``."""
        ...


class HostPlacement:
    """Bitsets stay in host numpy; dispatch is eager and unpadded."""

    kind = "host"
    store_word_tile = 1

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        return (
            np.asarray(bits),
            np.asarray(parent_counts, dtype=np.int64),
            int(tau),
            fused_classify,
        )

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        return m  # host gathers have no executable buckets to reuse

    def warm_buckets(
        self, n_words: int, *, fused: bool, write_children: bool
    ) -> tuple[int, ...]:
        return ()

    def dispatch(self, state, padded_pairs: np.ndarray, write_children: bool):
        _count_dispatch("dispatch", "host")
        bits, pc, tau, fused = state
        a = bits[padded_pairs[:, 0]]
        b = bits[padded_pairs[:, 1]]
        child = np.bitwise_and(a, b)
        counts = popcount_rows(child)
        classes = None
        if fused:
            minp = np.minimum(pc[padded_pairs[:, 0]], pc[padded_pairs[:, 1]])
            classes = _ops.classify_counts_host(counts, minp, tau)
        return (child if write_children else None), counts, classes

    def put_bits(self, bits: np.ndarray):
        return np.ascontiguousarray(bits)

    def prepare_coverage(self, bits):
        return np.ascontiguousarray(np.asarray(bits, dtype=np.uint32))

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        from ..kernels.coverage.ref import coverage_accumulate_host

        _count_dispatch("coverage", "host")
        return coverage_accumulate_host(state, padded_sets, padded_weights)

    # -- frontier (the numpy reference path, bit-identical by construction) --

    def prepare_frontier(self, itemsets, counts, n_symbols: int):
        from .support import ItemsetIndex

        return ItemsetIndex(itemsets, counts, n_symbols=n_symbols)

    def frontier_dispatch(self, state, lo: int, hi: int, n_pairs: int):
        """Numpy reference: materialise the span's candidate batch
        (``repeat``/``cumsum``) and run the packed-key support test — exactly
        the pre-frontier host path, shifted behind the placement API."""
        from .prefix import CandidateBatch, Level, generate_candidates
        from .support import support_test

        _count_dispatch("frontier", "host")
        itemsets = state.itemsets[lo:hi].astype(np.int32)
        counts = np.zeros(hi - lo, dtype=np.int64)
        batch = generate_candidates(Level(k=0, itemsets=itemsets, counts=counts, bits=None))
        batch = CandidateBatch(
            i_idx=batch.i_idx + lo, j_idx=batch.j_idx + lo, itemsets=batch.itemsets
        )
        return batch, support_test(batch.itemsets, state)

    def frontier_mask(self, state, pairs, ok):
        return pairs[ok], int(ok.sum())

    def frontier_partition(self, classes):
        order = np.argsort(classes, kind="stable")
        return order, int((classes == 1).sum()), int((classes == 2).sum())

    def release(self, state) -> None:
        pass  # host arrays are the caller's; nothing device-side to drop

    def describe(self) -> dict:
        return {"kind": self.kind, "engine": "numpy", "devices": 0}

    def __repr__(self) -> str:
        return "HostPlacement()"


class DevicePlacement:
    """One JAX device: the jnp oracle under jit or the Pallas kernels.

    Parent bitsets and popcounts upload once per level; every batch ships
    only the (tiny) padded pair list, and the bound dispatch callable is
    shared process-wide per bucket shape through ``ops.EXEC_CACHE``.
    """

    kind = "device"
    store_word_tile = 1

    def __init__(
        self,
        engine: str = "jnp",
        *,
        interpret: bool = True,
        indexed: bool = True,
        block_pairs: int = 8,
        block_words: int = 512,
    ):
        if engine not in ("jnp", "pallas"):
            raise ValueError(f"DevicePlacement engine must be jnp|pallas, got {engine!r}")
        self.engine = engine
        self.interpret = interpret
        self.indexed = indexed
        self.block_pairs = block_pairs
        self.block_words = block_words
        # gathered write path: donate the gathered operand on accelerator
        # backends so the child output aliases its buffer; CPU donation is
        # unsupported (warning + copy), so gate on backend.
        self.donate = jax.default_backend() in ("tpu", "gpu")

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        owned = not isinstance(bits, jax.Array)  # fresh upload -> releasable
        return (
            jnp.asarray(bits),
            jnp.asarray(np.asarray(parent_counts), dtype=jnp.int32),
            jnp.int32(int(tau)),
            int(bits.shape[1]),
            fused_classify,
            owned,
        )

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        return _ops.next_bucket(m) if pad_buckets else m

    def warm_buckets(
        self, n_words: int, *, fused: bool, write_children: bool
    ) -> tuple[int, ...]:
        # this placement's dispatch keys are the 10-tuples built below;
        # keep the positional reads in lockstep with that key layout
        buckets = set()
        for key in _ops.EXEC_CACHE.keys():
            if (
                len(key) == 10
                and key[0] == self.engine
                and key[1] == self.indexed
                and key[2] == fused
                and key[3] == write_children
                and key[4] == n_words
                and isinstance(key[5], int)
                and key[6] == self.block_pairs
                and key[7] == self.block_words
                and key[8] == self.interpret
                and key[9] == self.donate
            ):
                buckets.add(int(key[5]))
        return tuple(sorted(buckets))

    def dispatch(self, state, padded_pairs: np.ndarray, write_children: bool):
        _guard("dispatch")
        bits, pc, tau, n_words, fused, _owned = state
        bucket = int(padded_pairs.shape[0])
        key = (
            self.engine,
            self.indexed,
            fused,
            write_children,
            n_words,
            bucket,
            self.block_pairs,
            self.block_words,
            self.interpret,
            self.donate,
        )
        fn = _ops.EXEC_CACHE.get(
            key,
            lambda: _ops.build_engine_dispatch(
                self.engine,
                indexed=self.indexed,
                fused_classify=fused,
                write_children=write_children,
                n_words=n_words,
                bucket=bucket,
                block_pairs=self.block_pairs,
                block_words=self.block_words,
                interpret=self.interpret,
                donate=self.donate,
            ),
        )
        return fn(bits, jnp.asarray(padded_pairs), pc, tau)

    def put_bits(self, bits: np.ndarray):
        return jnp.asarray(bits)

    def prepare_coverage(self, bits):
        return jnp.asarray(bits)

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        _guard("coverage")
        from ..kernels.coverage import ops as _cov

        n_words = int(state.shape[1])
        bucket, width = int(padded_sets.shape[0]), int(padded_sets.shape[1])
        key = (
            "coverage",
            self.engine,
            width,
            n_words,
            bucket,
            self.block_words,
            self.interpret,
        )
        fn = _cov.EXEC_CACHE.get(
            key,
            lambda: _cov.build_coverage_dispatch(
                self.engine,
                n_words=n_words,
                block_words=self.block_words,
                interpret=self.interpret,
            ),
        )
        return fn(state, jnp.asarray(padded_sets), jnp.asarray(padded_weights))

    # -- frontier -----------------------------------------------------------

    def prepare_frontier(self, itemsets, counts, n_symbols: int):
        from ..kernels.frontier import ops as _fops

        itemsets = np.asarray(itemsets, dtype=np.int32)
        ids, keys, t_pad = _fops.make_level_tables(itemsets, n_symbols)
        from .prefix import group_reps

        return {
            "k": int(itemsets.shape[1]),
            "n_symbols": int(n_symbols),
            "t": int(itemsets.shape[0]),
            "t_pad": t_pad,
            "ids": jnp.asarray(ids),
            "keys": jnp.asarray(keys),
            "reps": group_reps(itemsets).astype(np.int32),
        }

    def frontier_dispatch(self, state, lo: int, hi: int, n_pairs: int):
        _guard("frontier")
        from ..kernels.frontier import ops as _fops

        row_bucket, bucket = _fops.gen_buckets(hi - lo, n_pairs)
        key = (
            "gen-support",
            state["k"],
            state["n_symbols"],
            state["t_pad"],
            row_bucket,
            bucket,
        )
        fn = _fops.EXEC_CACHE.get(
            key,
            lambda: _fops.build_gen_support(
                k=state["k"],
                n_symbols=state["n_symbols"],
                t_pad=state["t_pad"],
                row_bucket=row_bucket,
                bucket=bucket,
            ),
        )
        reps_b = _fops.pad_reps(state["reps"][lo:hi], row_bucket)
        return fn(
            state["ids"],
            state["keys"],
            jnp.asarray(reps_b),
            jnp.int32(lo),
            jnp.int32(n_pairs),
        )

    def frontier_mask(self, state, pairs, ok):
        from ..kernels.frontier import ops as _fops

        fn = _fops.mask_pruned  # module-level jit: re-traces per shape
        return fn(pairs, ok)

    def frontier_partition(self, classes):
        from ..kernels.frontier import ops as _fops

        fn = _fops.partition  # module-level jit: re-traces per shape
        return fn(classes)

    def release(self, state) -> None:
        """Retire a level eagerly: delete the device buffers this placement
        uploaded itself. Arrays the caller passed in (an already-resident
        ``jax.Array`` — e.g. the dataset store's version cache, or child
        bitsets chained from the previous level) are left alone."""
        if isinstance(state, dict):  # frontier state: ids/keys are uploads
            for name in ("ids", "keys"):
                arr = state.get(name)
                if isinstance(arr, jax.Array) and not arr.is_deleted():
                    arr.delete()
            return
        if isinstance(state, tuple) and len(state) == 6:
            bits, pc, *_rest, owned = state
            if owned:
                for arr in (bits, pc):
                    if isinstance(arr, jax.Array) and not arr.is_deleted():
                        arr.delete()

    def describe(self) -> dict:
        return {
            "kind": self.kind,
            "engine": self.engine,
            "devices": 1,
            "backend": jax.default_backend(),
            "indexed": self.indexed,
            "interpret": self.interpret,
        }

    def __repr__(self) -> str:
        return f"DevicePlacement(engine={self.engine!r})"


# Which (padded_words, bucket) shapes each mesh step-fn variant has already
# traced. Mesh executables are shape-polymorphic jits (one EXEC_CACHE entry
# per variant, retraced per input shape inside jax's own jit cache), so the
# warm-bucket question — "which batch sizes are free?" — is answered by
# *recording dispatched shapes* rather than enumerating cache keys the way
# DevicePlacement does. Stale entries after an exec-cache reset are harmless:
# a warm hint only changes padding, never results.
_MESH_WARM: dict[tuple, set[tuple[int, int]]] = {}


class MeshPlacement:
    """SPMD mesh: pairs shard over ``pair_axes``, words over ``word_axis``.

    The level body is a ``shard_map`` whose only collective is the word-axis
    popcount ``psum`` (classification happens after it, per pair shard, with
    zero extra communication).  Stored bitset matrices placed through
    :meth:`put_bits` must have a word count that is a multiple of
    :attr:`store_word_tile` (= the word-shard count) — the ``DatasetStore``
    aligns its tile to this, so serving a mesh never re-packs bits.

    ``word_axis`` may be one axis name or a tuple of names — the hybrid
    DCN x ICI layout shards words over both the in-host and the cross-host
    axes.  A mesh whose devices span processes flips the placement into its
    process-spanning variants: host arrays are placed shard-by-shard with
    ``jax.make_array_from_callback`` (a plain ``device_put`` cannot address
    remote shards), and the step bodies all-gather per-pair outputs over the
    pair axes (``replicate=True`` in ``core.sharded``) so counts and class
    codes materialize host-side on every process without touching
    non-addressable shards.
    """

    kind = "mesh"

    def __init__(
        self,
        mesh: Mesh,
        *,
        pair_axes: tuple[str, ...] = ("data",),
        word_axis: str | tuple[str, ...] | None = None,
        device_frontier: bool | None = None,
    ):
        self.mesh = mesh
        self.pair_axes = tuple(pair_axes)
        self.word_axis = tuple(word_axis) if isinstance(word_axis, list) else word_axis
        # mesh frontier ops re-shard stored children between levels, so each
        # batch runs a handful of small collectives (partition cumsum, child
        # all-gather). Real accelerator backends do these in microseconds;
        # the forced-host CPU mesh emulates them with thread rendezvous that
        # stalls for seconds. Same gating idiom as the donating kernels:
        # default on for tpu/gpu, opt-in (tests, experiments) on cpu.
        self.use_device_frontier = (
            jax.default_backend() in ("tpu", "gpu")
            if device_frontier is None
            else device_frontier
        )
        self.pair_shards = int(np.prod([mesh.shape[a] for a in self.pair_axes]))
        word_axes = (
            (word_axis,) if isinstance(word_axis, str) else tuple(word_axis or ())
        )
        self.word_shards = int(np.prod([mesh.shape[a] for a in word_axes])) if word_axes else 1
        self.store_word_tile = self.word_shards
        self.spans_processes = (
            len({d.process_index for d in mesh.devices.flat}) > 1
        )
        self._bits_sharding = NamedSharding(mesh, P(None, self.word_axis))
        self._pairs_sharding = NamedSharding(mesh, P(self.pair_axes, None))
        self._minp_sharding = NamedSharding(mesh, P(self.pair_axes))

    def _put(self, arr, sharding):
        """Place one array under ``sharding`` — the process-spanning variant
        assembles it from per-shard callbacks (every process feeds its own
        addressable shards from the replicated host copy)."""
        if self.spans_processes and not isinstance(arr, jax.Array):
            host = np.asarray(arr)
            return jax.make_array_from_callback(
                host.shape, sharding, lambda idx: host[idx]
            )
        return jax.device_put(arr, sharding)

    # the jitted shard_map bodies are bound once per (mesh, axes, variant)
    # through EXEC_CACHE, so executables are shared across levels, placements
    # of the same mesh, and mining requests (warm-start on the service).
    def _step_fn(self, fused: bool, write_children: bool):
        from . import sharded as _sh

        replicate = self.spans_processes
        key = (
            "mesh",
            self.mesh,
            self.pair_axes,
            self.word_axis,
            fused,
            write_children,
            replicate,
        )

        def build():
            if fused:
                builder = (
                    _sh.sharded_level_classify_step
                    if write_children
                    else _sh.sharded_level_classify_count_step
                )
            else:
                builder = (
                    _sh.sharded_level_step if write_children else _sh.sharded_level_count_step
                )
            fn, _, _ = builder(
                self.mesh,
                pair_axes=self.pair_axes,
                word_axis=self.word_axis,
                replicate=replicate,
            )
            return fn

        return _ops.EXEC_CACHE.get(key, build)

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        owned = not isinstance(bits, jax.Array)  # fresh placement -> releasable
        pc = np.asarray(parent_counts, dtype=np.int32)
        return (
            self.put_bits(bits),
            pc,
            jnp.asarray(pc),  # device copy for device-generated pair batches
            jnp.int32(int(tau)),
            fused_classify,
            owned,
        )

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        from .balance import balanced_blocks

        bucket = _ops.next_bucket(m) if pad_buckets else m
        padded_m, _ = balanced_blocks(bucket, self.pair_shards)
        return padded_m

    def _warm_key(self, fused: bool, write_children: bool) -> tuple:
        return ("mesh", self.mesh, self.pair_axes, self.word_axis, fused, write_children)

    def warm_buckets(
        self, n_words: int, *, fused: bool, write_children: bool
    ) -> tuple[int, ...]:
        # mesh step fns are shape-polymorphic jits, so "warm" means "this
        # (words, bucket) shape was already traced" — dispatched shapes are
        # recorded in _MESH_WARM (see its note). Queries arrive at the
        # store's word count; executables trace at the shard-padded width.
        pw = n_words + (-n_words) % max(self.word_shards, 1)
        shapes = _MESH_WARM.get(self._warm_key(fused, write_children), ())
        return tuple(sorted(b for w, b in shapes if w == pw))

    def dispatch(self, state, padded_pairs, write_children: bool):
        _guard("dispatch", "mesh")
        bits, pc, pc_dev, tau, fused, _owned = state
        device_pairs = isinstance(padded_pairs, jax.Array)
        pairs_j = self._put(
            padded_pairs if device_pairs else np.ascontiguousarray(padded_pairs),
            self._pairs_sharding,
        )
        _MESH_WARM.setdefault(self._warm_key(fused, write_children), set()).add(
            (int(bits.shape[1]), int(padded_pairs.shape[0]))
        )
        if not fused:
            fn = self._step_fn(False, write_children)
            if write_children:
                child, cnt = fn(bits, pairs_j)
                return child, cnt, None
            return None, fn(bits, pairs_j), None
        # padding rows are self-pairs, so their minp is their parent count and
        # the fused classifier marks them CLASS_SKIP (count == min parent
        # count). Device-generated frontier batches never leave the device:
        # their minp gathers from the resident count copy.
        if device_pairs:
            minp = jnp.minimum(pc_dev[padded_pairs[:, 0]], pc_dev[padded_pairs[:, 1]])
            minp_j = jax.device_put(minp, self._minp_sharding)
        else:
            minp_j = self._put(
                np.minimum(pc[padded_pairs[:, 0]], pc[padded_pairs[:, 1]]),
                self._minp_sharding,
            )
        fn = self._step_fn(True, write_children)
        if write_children:
            return fn(bits, pairs_j, minp_j, tau)
        cnt, cls = fn(bits, pairs_j, minp_j, tau)
        return None, cnt, cls

    def put_bits(self, bits):
        """Word-shard a bitset matrix over the mesh.  Host arrays are padded
        to the shard multiple first (zero words = no rows); arrays already
        tile-aligned — the dataset store's layout — ship with zero re-packing
        copies, and jax arrays already on the mesh reshard in place."""
        if not isinstance(bits, jax.Array):
            from .sharded import pad_words

            bits = pad_words(np.ascontiguousarray(bits), self.word_shards)
        return self._put(bits, self._bits_sharding)

    def prepare_coverage(self, bits):
        return self.put_bits(bits)

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        _guard("coverage", "mesh")
        from ..kernels.coverage import ops as _cov
        from . import sharded as _sh

        width = int(padded_sets.shape[1])
        key = ("coverage-mesh", self.mesh, self.pair_axes, self.word_axis, width)
        fn = _cov.EXEC_CACHE.get(
            key,
            lambda: _sh.sharded_coverage_step(
                self.mesh,
                pair_axes=self.pair_axes,
                word_axis=self.word_axis,
                n_set_items=width,
            )[0],
        )
        sets_j = self._put(np.ascontiguousarray(padded_sets), self._pairs_sharding)
        wt_j = self._put(np.ascontiguousarray(padded_weights), self._minp_sharding)
        return fn(state, sets_j, wt_j)

    # -- frontier -----------------------------------------------------------

    def prepare_frontier(self, itemsets, counts, n_symbols: int):
        from ..kernels.frontier import ops as _fops
        from .prefix import group_reps

        itemsets = np.asarray(itemsets, dtype=np.int32)
        ids, keys, t_pad = _fops.make_level_tables(itemsets, n_symbols)
        repl = NamedSharding(self.mesh, P(None, None))
        return {
            "k": int(itemsets.shape[1]),
            "n_symbols": int(n_symbols),
            "t": int(itemsets.shape[0]),
            "t_pad": t_pad,
            # id/key tables replicate over the mesh (the shared-memory
            # analogue); only the pair axis of the support test shards
            "ids": self._put(np.asarray(ids), repl),
            "keys": self._put(np.asarray(keys), repl),
            "reps": group_reps(itemsets).astype(np.int32),
        }

    def frontier_dispatch(self, state, lo: int, hi: int, n_pairs: int):
        _guard("frontier", "mesh")
        from ..kernels.frontier import ops as _fops
        from ..kernels.frontier.frontier import pack_params
        from . import sharded as _sh

        row_bucket = _fops.next_bucket(hi - lo, 16)
        bucket = self.padded_size(n_pairs)
        gen_fn = _fops.EXEC_CACHE.get(
            ("gen", row_bucket, bucket), lambda: _fops.build_gen(bucket=bucket)
        )
        reps_b = _fops.pad_reps(state["reps"][lo:hi], row_bucket)
        pairs, valid = gen_fn(jnp.asarray(reps_b), jnp.int32(lo), jnp.int32(n_pairs))
        if state["k"] < 2:  # candidate width 2: both subsets stored parents
            return pairs, valid
        bits_, ipw, _ = pack_params(state["n_symbols"], state["k"])
        key = (
            "mesh-support",
            self.mesh,
            self.pair_axes,
            state["k"],
            state["n_symbols"],
            state["t_pad"],
            bucket,
            self.spans_processes,
        )
        fn = _fops.EXEC_CACHE.get(
            key,
            lambda: _sh.sharded_frontier_support_step(
                self.mesh,
                pair_axes=self.pair_axes,
                k=state["k"],
                t_pad=state["t_pad"],
                bits=bits_,
                ipw=ipw,
                replicate=self.spans_processes,
            )[0],
        )
        if self.spans_processes:
            # generated on the default device; re-place shard-by-shard (a
            # cross-process device_put reshard is not addressable)
            pairs_sh = self._put(np.asarray(pairs), self._pairs_sharding)
            valid_sh = self._put(np.asarray(valid), self._minp_sharding)
        else:
            pairs_sh = jax.device_put(pairs, self._pairs_sharding)
            valid_sh = jax.device_put(valid, self._minp_sharding)
        ok = fn(state["ids"], state["keys"], pairs_sh, valid_sh)
        return pairs, ok

    def frontier_mask(self, state, pairs, ok):
        from ..kernels.frontier import ops as _fops

        fn = _fops.mask_pruned  # module-level jit: re-traces per shape
        return fn(jnp.asarray(pairs), jnp.asarray(ok))

    def frontier_partition(self, classes):
        from ..kernels.frontier import ops as _fops

        fn = _fops.partition  # module-level jit: re-traces per shape
        return fn(jnp.asarray(classes))

    def release(self, state) -> None:
        """Eager level retirement on the mesh — same ownership rule as the
        single-device placement (see :meth:`DevicePlacement.release`)."""
        if isinstance(state, dict):
            for name in ("ids", "keys"):
                arr = state.get(name)
                if isinstance(arr, jax.Array) and not arr.is_deleted():
                    arr.delete()
            return
        if isinstance(state, tuple) and len(state) == 6:
            bits, _pc, pc_dev, *_rest, owned = state
            if owned:
                for arr in (bits, pc_dev):
                    if isinstance(arr, jax.Array) and not arr.is_deleted():
                        arr.delete()

    def describe(self) -> dict:
        return {
            "kind": self.kind,
            "devices": int(np.prod(list(self.mesh.shape.values()))),
            "mesh_shape": dict(self.mesh.shape),
            "pair_axes": list(self.pair_axes),
            "word_axis": (
                list(self.word_axis)
                if isinstance(self.word_axis, tuple)
                else self.word_axis
            ),
            "pair_shards": self.pair_shards,
            "word_shards": self.word_shards,
            "spans_processes": self.spans_processes,
        }

    def __repr__(self) -> str:
        return (
            f"MeshPlacement(shape={dict(self.mesh.shape)}, "
            f"pair_axes={self.pair_axes}, word_axis={self.word_axis!r})"
        )


def make_placement(
    engine: str,
    *,
    interpret: bool = True,
    indexed: bool = True,
    block_pairs: int = 8,
    block_words: int = 512,
) -> BitsetPlacement:
    """Placement for an engine name: ``numpy``/``host`` -> host,
    ``jnp``/``pallas`` -> single device."""
    if engine in ("numpy", "host"):
        return HostPlacement()
    if engine in ("jnp", "pallas"):
        return DevicePlacement(
            engine,
            interpret=interpret,
            indexed=indexed,
            block_pairs=block_pairs,
            block_words=block_words,
        )
    raise ValueError(
        f"no placement for engine {engine!r} (expected numpy|jnp|pallas; "
        "meshes are constructed explicitly via MeshPlacement)"
    )


def resolve_placement(config) -> BitsetPlacement:
    """The one factory between ``KyivConfig`` and a placement.

    ``config.placement`` wins when set (a :class:`BitsetPlacement` instance,
    or an engine-name string resolved through :func:`make_placement`);
    otherwise the legacy ``config.engine`` string selects host or
    single-device placement with the config's kernel knobs.
    """
    p = getattr(config, "placement", None)
    if p is not None and not isinstance(p, str):
        return p
    engine = p if isinstance(p, str) else config.engine
    return make_placement(
        engine,
        interpret=getattr(config, "interpret", True),
        indexed=getattr(config, "indexed_kernel", True),
    )
