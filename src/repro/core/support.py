"""The zero-cost support-itemset test (paper §4.4.1) via packed-key lookup.

Because the BFS driver stores the whole previous level, testing whether every
``(k-1)``-subset of a candidate ``W`` survives reduces to table lookups
(Alg. 1 line 23). We realise the lookup with a sorted packed-key index:

* when ``k * bits_per_item <= 64`` the itemset packs exactly into a uint64 and
  ``searchsorted`` gives an exact match;
* otherwise rows are hashed (splitmix64 mix per column) into uint64, searched,
  and verified column-wise within the (astronomically rare) collision bucket —
  the result stays exact.

Both paths are fully vectorised numpy; the per-candidate device cost is zero,
which is precisely the paper's point.
"""

from __future__ import annotations

import numpy as np

__all__ = ["ItemsetIndex", "support_test"]

_MIX = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _splitmix(h: np.ndarray) -> np.ndarray:
    h = (h ^ (h >> np.uint64(30))) * _MIX
    h = (h ^ (h >> np.uint64(27))) * _MIX2
    return h ^ (h >> np.uint64(31))


class ItemsetIndex:
    """Exact lookup index over a lexicographically sorted (t, k) int32 table."""

    def __init__(self, itemsets: np.ndarray, counts: np.ndarray | None = None, n_symbols: int | None = None):
        itemsets = np.asarray(itemsets, dtype=np.int64)
        self.itemsets = itemsets
        self.counts = None if counts is None else np.asarray(counts, dtype=np.int64)
        t, k = itemsets.shape
        self.k = k
        if n_symbols is None:
            n_symbols = int(itemsets.max()) + 1 if t else 1
        bits = max(1, int(n_symbols - 1).bit_length())
        self.exact = k * bits <= 64
        if self.exact:
            self._keys = self._pack_exact(itemsets, bits)
            self._bits = bits
        else:
            self._keys = self._hash(itemsets)
        self._order = np.argsort(self._keys, kind="stable")
        self._sorted_keys = self._keys[self._order]

    @staticmethod
    def _pack_exact(itemsets: np.ndarray, bits: int) -> np.ndarray:
        keys = np.zeros(itemsets.shape[0], dtype=np.uint64)
        for c in range(itemsets.shape[1]):
            keys = (keys << np.uint64(bits)) | itemsets[:, c].astype(np.uint64)
        return keys

    @staticmethod
    def _hash(itemsets: np.ndarray) -> np.ndarray:
        h = np.full(itemsets.shape[0], 0x51ED270B, dtype=np.uint64)
        for c in range(itemsets.shape[1]):
            h = _splitmix(h ^ itemsets[:, c].astype(np.uint64))
        return h

    def lookup(self, queries: np.ndarray) -> np.ndarray:
        """Row index of each query (q, k) itemset, or -1 when absent."""
        queries = np.asarray(queries, dtype=np.int64)
        if queries.ndim != 2 or queries.shape[1] != self.k:
            raise ValueError(f"queries must be (q, {self.k}), got {queries.shape}")
        if self.itemsets.shape[0] == 0 or queries.shape[0] == 0:
            return np.full(queries.shape[0], -1, dtype=np.int64)
        qk = self._pack_exact(queries, self._bits) if self.exact else self._hash(queries)
        pos = np.searchsorted(self._sorted_keys, qk)
        pos_c = np.minimum(pos, len(self._sorted_keys) - 1)
        hit = self._sorted_keys[pos_c] == qk
        rows = np.where(hit, self._order[pos_c], -1)
        if not self.exact:
            # verify (collisions possible): compare actual columns; on mismatch,
            # scan the equal-key run (runs are overwhelmingly length 1).
            cand = rows >= 0
            if cand.any():
                ok = np.all(self.itemsets[rows[cand]] == queries[cand], axis=1)
                bad = np.nonzero(cand)[0][~ok]
                for qi in bad:
                    rows[qi] = self._scan_run(int(pos[qi]), queries[qi])
        return rows

    def _scan_run(self, start: int, query: np.ndarray) -> int:
        key = self._hash(query[None])[0]
        i = start
        while i < len(self._sorted_keys) and self._sorted_keys[i] == key:
            row = self._order[i]
            if np.array_equal(self.itemsets[row], query):
                return int(row)
            i += 1
        return -1

    def lookup_counts(self, queries: np.ndarray, default: int = -1) -> np.ndarray:
        """Counts |R_S| for each query; ``default`` where absent."""
        if self.counts is None:
            raise ValueError("index built without counts")
        rows = self.lookup(queries)
        out = np.full(len(rows), default, dtype=np.int64)
        hit = rows >= 0
        out[hit] = self.counts[rows[hit]]
        return out


def support_test(candidates: np.ndarray, parent_index: ItemsetIndex) -> np.ndarray:
    """Alg. 1 line 23: True where **all** (k-1)-subsets of W survive in level k-1.

    The two subsets W\\{a} = J and W\\{b} = I are present by construction
    (candidates come from joining stored rows), so only the ``k-2`` subsets
    obtained by dropping a prefix position need lookups.
    """
    m, k = candidates.shape
    ok = np.ones(m, dtype=bool)
    if m == 0 or k <= 2:
        return ok  # k=2: both subsets are the (stored) singleton parents
    cols = np.arange(k)
    for drop in range(k - 2):  # drop each prefix position
        sub = candidates[:, cols != drop]
        ok &= parent_index.lookup(sub) >= 0
    return ok
