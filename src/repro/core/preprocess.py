"""Pre-processing of the item table (paper §4.1) and item ordering (Def. 4.5).

Steps, exactly as the paper prescribes:
  1. Uniform items ``U_A`` (``|R_a| = n``) are dropped — they cannot belong to
     a minimal τ-infrequent itemset.
  2. τ-infrequent single items ``r_{A,τ}`` (``|R_a| <= τ``) are emitted
     directly — items are trivially minimal.
  3. The remaining items ``I'_{A,τ}`` are partitioned into a canonical set
     ``L_{A,τ}`` with pairwise-distinct row sets and a mirror set ``L̄`` of
     duplicates (Propositions 4.1/4.2): mining runs on ``L`` only and every
     result involving a canonical item ``w`` expands to results for every
     mirror ``w'`` with ``R_w = R_{w'}``.
  4. ``L`` is sorted ascending (Def. 4.5): by ``(|R_a|, j_a, min R_a)``.

Duplicate row-set detection hashes bitset rows (exact: hash, then verify
within hash buckets) — O(items × W).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .items import ItemTable

__all__ = ["Preprocessed", "preprocess", "ORDERINGS"]

ORDERINGS = ("ascending", "descending", "random")


@dataclasses.dataclass
class Preprocessed:
    """Output of §4.1 pre-processing.

    Attributes:
      table: the original item table.
      tau: threshold used.
      uniform_items: ids in ``U_A``.
      infrequent_items: ids in ``r_{A,τ}`` (emitted as 1-itemsets).
      l_items: ids of ``L_{A,τ}`` in the chosen order (``L^<`` when ascending).
      mirror_of: dict canonical item id -> list of duplicate item ids (``L̄``).
      l_bits: (|L|, W) uint32 bitsets of ``L`` rows, ordered like ``l_items``.
      l_freq: (|L|,) frequencies, same order.
    """

    table: ItemTable
    tau: int
    uniform_items: np.ndarray
    infrequent_items: np.ndarray
    l_items: np.ndarray
    mirror_of: dict[int, list[int]]
    l_bits: np.ndarray
    l_freq: np.ndarray

    @property
    def n_l(self) -> int:
        return int(self.l_items.shape[0])


def _row_set_groups(table: ItemTable, ids: np.ndarray) -> list[np.ndarray]:
    """Group item ids by identical row sets (bitset rows). Exact.

    Returns a list of arrays; each array holds the ids sharing one row set,
    in ascending item-id order.
    """
    if len(ids) == 0:
        return []
    sub = table.bits[ids]  # (g, W)
    # Hash each row, then verify within buckets to keep exactness.
    mix = np.uint64(0x9E3779B97F4A7C15)
    h = np.zeros(len(ids), dtype=np.uint64)
    for w in range(sub.shape[1]):
        h = (h ^ sub[:, w].astype(np.uint64)) * mix
        h ^= h >> np.uint64(29)
    order = np.argsort(h, kind="stable")
    groups: list[np.ndarray] = []
    i = 0
    ordered = ids[order]
    hs = h[order]
    while i < len(ordered):
        j = i + 1
        while j < len(ordered) and hs[j] == hs[i]:
            j += 1
        bucket = ordered[i:j]
        if len(bucket) == 1:
            groups.append(bucket)
        else:
            # verify exact equality within the hash bucket
            rem = list(bucket)
            while rem:
                head = rem[0]
                same = [x for x in rem if np.array_equal(table.bits[x], table.bits[head])]
                groups.append(np.asarray(sorted(same), dtype=np.int64))
                rem = [x for x in rem if x not in same]
        i = j
    return groups


def preprocess(
    table: ItemTable,
    tau: int,
    ordering: str = "ascending",
    seed: int = 0,
) -> Preprocessed:
    """Run §4.1 pre-processing + Def. 4.5 ordering on an item table."""
    if tau <= 0:
        raise ValueError(f"tau must be positive (Def. 3.3 usage), got {tau}")
    if ordering not in ORDERINGS:
        raise ValueError(f"ordering must be one of {ORDERINGS}, got {ordering!r}")

    n = table.n_rows
    freq = table.freq
    uniform = np.nonzero(freq == n)[0]
    infrequent = np.nonzero(freq <= tau)[0]
    # Uniform items with n <= tau would satisfy both; the paper confines τ < n.
    keep_mask = (freq > tau) & (freq < n)
    remaining = np.nonzero(keep_mask)[0]

    groups = _row_set_groups(table, remaining)
    canonical = np.asarray([int(g[0]) for g in groups], dtype=np.int64)
    mirror_of = {int(g[0]): [int(x) for x in g[1:]] for g in groups if len(g) > 1}

    if ordering == "ascending":
        order = np.lexsort(
            (table.min_row[canonical], table.col[canonical], table.freq[canonical])
        )
    elif ordering == "descending":
        order = np.lexsort(
            (table.min_row[canonical], table.col[canonical], table.freq[canonical])
        )[::-1]
    else:  # random
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(canonical))
    l_items = canonical[order]

    return Preprocessed(
        table=table,
        tau=tau,
        uniform_items=uniform,
        infrequent_items=infrequent,
        l_items=l_items,
        mirror_of=mirror_of,
        l_bits=np.ascontiguousarray(table.bits[l_items]),
        l_freq=table.freq[l_items].astype(np.int64),
    )
