"""Pre-processing of the item table (paper §4.1) and item ordering (Def. 4.5).

Steps, exactly as the paper prescribes:
  1. Uniform items ``U_A`` (``|R_a| = n``) are dropped — they cannot belong to
     a minimal τ-infrequent itemset.
  2. τ-infrequent single items ``r_{A,τ}`` (``|R_a| <= τ``) are emitted
     directly — items are trivially minimal.
  3. The remaining items ``I'_{A,τ}`` are partitioned into a canonical set
     ``L_{A,τ}`` with pairwise-distinct row sets and a mirror set ``L̄`` of
     duplicates (Propositions 4.1/4.2): mining runs on ``L`` only and every
     result involving a canonical item ``w`` expands to results for every
     mirror ``w'`` with ``R_w = R_{w'}``.
  4. ``L`` is sorted ascending (Def. 4.5): by ``(|R_a|, j_a, min R_a)``.

Duplicate row-set detection hashes bitset rows (exact: hash, then verify
within hash buckets) — O(items × W).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .items import ItemTable

__all__ = ["Preprocessed", "preprocess", "set_row_group_collective", "ORDERINGS"]

ORDERINGS = ("ascending", "descending", "random")

# Fleet rendezvous for duplicate-row-set detection: with process-sharded
# bitsets each process sees only its word stripes, so neither the hashes nor
# the exact verification are decidable locally. When a collective is
# installed, `_row_set_groups` combines all-gathered per-item hashes into a
# global hash and AND-reduces the within-bucket equality flags — two
# collective rounds per preprocess, after which every process holds the
# identical canonical/mirror partition.
_ROW_GROUP_COLLECTIVE = None


def set_row_group_collective(coll):
    """Install the fleet collective (``repro.core.collective``) used to agree
    on duplicate row sets; ``None`` restores local-only grouping. Returns the
    previous value so callers can restore it."""
    global _ROW_GROUP_COLLECTIVE
    prev, _ROW_GROUP_COLLECTIVE = _ROW_GROUP_COLLECTIVE, coll
    return prev


@dataclasses.dataclass
class Preprocessed:
    """Output of §4.1 pre-processing.

    Attributes:
      table: the original item table.
      tau: threshold used.
      uniform_items: ids in ``U_A``.
      infrequent_items: ids in ``r_{A,τ}`` (emitted as 1-itemsets).
      l_items: ids of ``L_{A,τ}`` in the chosen order (``L^<`` when ascending).
      mirror_of: dict canonical item id -> list of duplicate item ids (``L̄``).
      l_bits: (|L|, W) uint32 bitsets of ``L`` rows, ordered like ``l_items``.
      l_freq: (|L|,) frequencies, same order.
    """

    table: ItemTable
    tau: int
    uniform_items: np.ndarray
    infrequent_items: np.ndarray
    l_items: np.ndarray
    mirror_of: dict[int, list[int]]
    l_bits: np.ndarray
    l_freq: np.ndarray

    @property
    def n_l(self) -> int:
        return int(self.l_items.shape[0])


def _row_set_groups(table: ItemTable, ids: np.ndarray) -> list[np.ndarray]:
    """Group item ids by identical row sets (bitset rows). Exact.

    Returns a list of arrays; each array holds the ids sharing one row set,
    in ascending item-id order.
    """
    if len(ids) == 0:
        return []
    sub = table.bits[ids]  # (g, W)
    # Hash each row, then verify within buckets to keep exactness.
    mix = np.uint64(0x9E3779B97F4A7C15)
    h = np.zeros(len(ids), dtype=np.uint64)
    for w in range(sub.shape[1]):
        h = (h ^ sub[:, w].astype(np.uint64)) * mix
        h ^= h >> np.uint64(29)
    coll = _ROW_GROUP_COLLECTIVE
    if coll is not None:
        # round 1: fold every process's local hashes (pid order is fixed by
        # the all-gather) into one global hash — equal rows hash equal
        # everywhere, so the buckets below agree across the fleet
        mix2 = np.uint64(0xBF58476D1CE4E5B9)
        combined = np.zeros_like(h)
        for payload in coll.allgather(np.ascontiguousarray(h).tobytes()):
            ph = np.frombuffer(payload, dtype=np.uint64)
            combined = (combined ^ ph) * mix2
            combined ^= combined >> np.uint64(31)
        h = combined
    order = np.argsort(h, kind="stable")
    ordered = ids[order]
    hs = h[order]
    buckets: list[np.ndarray] = []
    i = 0
    while i < len(ordered):
        j = i + 1
        while j < len(ordered) and hs[j] == hs[i]:
            j += 1
        buckets.append(ordered[i:j])
        i = j
    # exact verification within each multi-element bucket: all pairwise
    # equality flags in one flat vector. Locally that is just array_equal;
    # under a collective the flags AND-reduce (round 2: sum == nproc) so a
    # pair is grouped only when its rows agree on *every* process's stripes.
    multis = [b for b in buckets if len(b) > 1]
    eq_of: dict[int, np.ndarray] = {}
    if multis:
        flags = []
        for b in multis:
            rows = table.bits[b]  # (g, W)
            eq = (rows[:, None, :] == rows[None, :, :]).all(axis=2)
            flags.append(eq[np.triu_indices(len(b), 1)])
        flat = np.concatenate(flags).astype(np.int64)
        if coll is not None:
            flat = coll.allreduce_sum(flat) == coll.nproc
        else:
            flat = flat.astype(bool)
        off = 0
        for bi, b in enumerate(multis):
            g = len(b)
            npairs = g * (g - 1) // 2
            eq = np.eye(g, dtype=bool)
            iu = np.triu_indices(g, 1)
            eq[iu] = flat[off : off + npairs]
            eq.T[iu] = flat[off : off + npairs]
            eq_of[bi] = eq
            off += npairs
    groups: list[np.ndarray] = []
    bi = 0
    for bucket in buckets:
        if len(bucket) == 1:
            groups.append(bucket)
            continue
        eq = eq_of[bi]
        bi += 1
        rem = list(range(len(bucket)))
        while rem:
            head = rem[0]
            same = [r for r in rem if eq[head, r]]
            groups.append(np.asarray(sorted(int(bucket[r]) for r in same), dtype=np.int64))
            rem = [r for r in rem if r not in same]
    return groups


def preprocess(
    table: ItemTable,
    tau: int,
    ordering: str = "ascending",
    seed: int = 0,
) -> Preprocessed:
    """Run §4.1 pre-processing + Def. 4.5 ordering on an item table."""
    if tau <= 0:
        raise ValueError(f"tau must be positive (Def. 3.3 usage), got {tau}")
    if ordering not in ORDERINGS:
        raise ValueError(f"ordering must be one of {ORDERINGS}, got {ordering!r}")

    n = table.n_rows
    freq = table.freq
    uniform = np.nonzero(freq == n)[0]
    infrequent = np.nonzero(freq <= tau)[0]
    # Uniform items with n <= tau would satisfy both; the paper confines τ < n.
    keep_mask = (freq > tau) & (freq < n)
    remaining = np.nonzero(keep_mask)[0]

    groups = _row_set_groups(table, remaining)
    canonical = np.asarray([int(g[0]) for g in groups], dtype=np.int64)
    mirror_of = {int(g[0]): [int(x) for x in g[1:]] for g in groups if len(g) > 1}

    if ordering == "ascending":
        order = np.lexsort(
            (table.min_row[canonical], table.col[canonical], table.freq[canonical])
        )
    elif ordering == "descending":
        order = np.lexsort(
            (table.min_row[canonical], table.col[canonical], table.freq[canonical])
        )[::-1]
    else:  # random
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(canonical))
    l_items = canonical[order]

    return Preprocessed(
        table=table,
        tau=tau,
        uniform_items=uniform,
        infrequent_items=infrequent,
        l_items=l_items,
        mirror_of=mirror_of,
        l_bits=np.ascontiguousarray(table.bits[l_items]),
        l_freq=table.freq[l_items].astype(np.int64),
    )
