"""One process-wide executable cache, namespaced per kernel family.

Until PR 5 every kernel family kept its own ``ExecutableCache`` instance
(``kernels.intersect.ops.EXEC_CACHE`` and ``kernels.coverage.ops.EXEC_CACHE``),
which meant two hit/miss surfaces in ``/stats`` and a third was about to
appear for the frontier ops. This module is the single shared registry:

* :class:`SharedExecutableCache` holds one ``(family, key) -> callable`` map
  with per-family hit/miss/entry counters behind one lock;
* :meth:`SharedExecutableCache.family` hands out a :class:`FamilyCache` view
  whose ``get``/``stats``/``clear`` API is exactly what the old per-family
  instances exposed, so every existing call site keeps working;
* :func:`stats` is the one observability surface — per-family counters plus
  process totals — reported as the single ``executables`` section of the
  service's ``/stats``.

Import discipline: this module is a **leaf** (stdlib only). The kernels
packages import it, and ``repro.core`` re-exports it — kernels must never
import anything else from ``repro.core`` (core imports kernels, and the
reverse edge would cycle). ``kernels/*/ops.py`` therefore bind their family
views where their module bodies no longer need anything from core's
``__init__`` to have finished executing (see the note at the bottom of
``kernels/intersect/ops.py``).
"""

from __future__ import annotations

import threading
from typing import Any, Callable

__all__ = [
    "ExecutableCache",
    "FamilyCache",
    "SharedExecutableCache",
    "SHARED_EXEC_CACHE",
    "exec_family",
    "stats",
    "reset",
    "publish_metrics",
]


class SharedExecutableCache:
    """Process-wide cache of bound batch-dispatch callables, keyed by
    ``(family, key)``.

    One entry per executable bucket — ``jax.jit`` already memoises compiled
    executables by shape, but the dispatch-branch selection, tile arithmetic
    and kernel-variant binding would otherwise be redone on every pipeline
    dispatch of every ``mine()`` call. Hoisting them here makes the bucket
    set shared across pipelines, levels, mining requests and kernel families
    (the resident service's warm start), and makes warm-vs-cold observable
    via per-family hit/miss counters.
    """

    def __init__(self):
        self._fns: dict[tuple, Any] = {}
        self._lock = threading.Lock()
        self._hits: dict[str, int] = {}
        self._misses: dict[str, int] = {}

    def get(self, family: str, key: tuple, builder: Callable[[], Any]):
        full = (family, key)
        with self._lock:
            fn = self._fns.get(full)
            if fn is not None:
                self._hits[family] = self._hits.get(family, 0) + 1
                return fn
            self._misses[family] = self._misses.get(family, 0) + 1
        fn = builder()
        with self._lock:
            # a racing builder may have beaten us; keep the first binding so
            # every caller shares one executable bucket
            fn = self._fns.setdefault(full, fn)
        return fn

    def family(self, name: str) -> "FamilyCache":
        return FamilyCache(self, name)

    def keys(self, family: str) -> list[tuple]:
        """The bound executable keys of one family — lets callers pad new
        dispatches to bucket sizes that already have executables (the
        sampling tier's boundary recount reuses warm buckets this way)."""
        with self._lock:
            return [k for fam, k in self._fns if fam == family]

    def family_stats(self, name: str) -> dict:
        with self._lock:
            entries = sum(1 for fam, _ in self._fns if fam == name)
            return {
                "entries": entries,
                "hits": self._hits.get(name, 0),
                "misses": self._misses.get(name, 0),
            }

    def stats(self) -> dict:
        """Per-family counters plus totals — the ``/stats`` payload."""
        with self._lock:
            families: dict[str, dict] = {}
            for fam, _ in self._fns:
                families.setdefault(fam, {"entries": 0})["entries"] += 1
            for fam in set(self._hits) | set(self._misses) | set(families):
                entry = families.setdefault(fam, {"entries": 0})
                entry["hits"] = self._hits.get(fam, 0)
                entry["misses"] = self._misses.get(fam, 0)
            return {
                "families": families,
                "entries": len(self._fns),
                "hits": sum(self._hits.values()),
                "misses": sum(self._misses.values()),
            }

    def clear(self, family: str | None = None) -> None:
        with self._lock:
            if family is None:
                self._fns.clear()
                self._hits.clear()
                self._misses.clear()
                return
            for full in [k for k in self._fns if k[0] == family]:
                del self._fns[full]
            self._hits.pop(family, None)
            self._misses.pop(family, None)


class FamilyCache:
    """One family's view of the shared cache — the drop-in replacement for
    the old per-module ``ExecutableCache`` instances (same ``get(key,
    builder)`` / ``stats()`` / ``clear()`` API and stats keys)."""

    def __init__(self, shared: SharedExecutableCache, name: str):
        self._shared = shared
        self.name = name

    def get(self, key: tuple, builder: Callable[[], Any]):
        return self._shared.get(self.name, key, builder)

    def keys(self) -> list[tuple]:
        return self._shared.keys(self.name)

    def stats(self) -> dict:
        return self._shared.family_stats(self.name)

    def clear(self) -> None:
        self._shared.clear(self.name)

    def __repr__(self) -> str:
        return f"FamilyCache({self.name!r})"


# Backwards-compatible alias: ``kernels.intersect.ExecutableCache`` used to
# name the standalone per-module class; family views are what replaced it.
ExecutableCache = FamilyCache

SHARED_EXEC_CACHE = SharedExecutableCache()


def exec_family(name: str) -> FamilyCache:
    """The named family view of the process-wide executable cache."""
    return SHARED_EXEC_CACHE.family(name)


def stats() -> dict:
    """Single observability surface over every kernel family's executables."""
    return SHARED_EXEC_CACHE.stats()


def reset(family: str | None = None) -> None:
    SHARED_EXEC_CACHE.clear(family)


def publish_metrics(registry=None) -> None:
    """Mirror the shared executable cache into a metrics registry as the
    ``exec_cache`` named collector (idempotent: re-registering replaces).

    The cache keeps its own counters — they predate the registry and the
    ``/stats`` ``executables`` section is built from them — so the bridge
    reads them at scrape time instead of double-counting at every ``get``.
    Imported lazily so this module stays a stdlib-only leaf for callers
    that never scrape metrics.
    """
    from ..obs import metrics as _om

    reg = registry or _om.REGISTRY
    g_entries = reg.gauge(
        "repro_exec_cache_entries",
        "Bound executables resident per kernel family.",
        ("family",),
    )
    c_hits = reg.counter(
        "repro_exec_cache_hits_total", "Executable-cache hits.", ("family",)
    )
    c_misses = reg.counter(
        "repro_exec_cache_misses_total", "Executable-cache misses.", ("family",)
    )

    def _collect():
        s = SHARED_EXEC_CACHE.stats()
        for fam, fs in s["families"].items():
            g_entries.set(fs["entries"], family=fam)
            c_hits.set_total(fs.get("hits", 0), family=fam)
            c_misses.set_total(fs.get("misses", 0), family=fam)

    reg.register_collector("exec_cache", _collect)
