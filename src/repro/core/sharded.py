"""Distributed (SPMD) intersection step for the Kyiv miner.

The paper parallelises level k with shared-memory threads (§4.4.4): the
stored level is shared, candidate pairs are divided among threads, and no
inter-thread communication happens during a level. The SPMD mapping:

  * candidate **pairs** shard over the ``data`` (and ``pod``) mesh axes —
    exactly-equal padded blocks (see ``core.balance.balanced_blocks``);
  * the parent-level **bitset words** optionally shard over ``model``
    (row-parallelism for datasets whose bitset rows exceed one device);
    per-shard partial popcounts are ``psum``-ed over ``model`` — the only
    collective in the level body, mirroring the paper's
    "no inter-thread communication" property;
  * the parent table is replicated over the pair axes (the shared-memory
    analogue). For the count-only (k = k_max) step no child bitsets are
    written, so per-device HBM traffic is the two fetched rows per pair.

``make_sharded_intersect`` returns a drop-in ``intersect_fn`` for
``mine_preprocessed`` — numerics are identical to the sequential engines
(tested on an 8-device CPU mesh in ``tests/test_sharded_driver.py``).

``sharded_level_step``/``sharded_level_count_step`` are the jittable bodies
the multi-pod dry-run lowers on the production meshes (the paper-technique
rows of the roofline table).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = [
    "sharded_level_step",
    "sharded_level_count_step",
    "make_sharded_intersect",
    "pad_words",
]


def pad_words(bits: np.ndarray, multiple: int) -> np.ndarray:
    """Pad the word dimension to a multiple (extra words are zero = no rows)."""
    t, w = bits.shape
    rem = (-w) % multiple
    if rem == 0:
        return bits
    return np.concatenate([bits, np.zeros((t, rem), dtype=bits.dtype)], axis=1)


def _local_intersect(bits_ref, pairs, *, word_axis: str | None, write_children: bool):
    a = jnp.take(bits_ref, pairs[:, 0], axis=0)
    b = jnp.take(bits_ref, pairs[:, 1], axis=0)
    child = jnp.bitwise_and(a, b)
    partial = jnp.sum(jax.lax.population_count(child).astype(jnp.int32), axis=1)
    counts = jax.lax.psum(partial, word_axis) if word_axis else partial
    if write_children:
        return child, counts
    return counts


def sharded_level_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
):
    """Build the write-variant level body: (bits, pairs) -> (child, counts).

    bits: (t, W) uint32, sharded P(None, word_axis);
    pairs: (M, 2) int32, sharded P(pair_axes, None);
    child: (M, W), sharded P(pair_axes, word_axis); counts: (M,) P(pair_axes).
    """
    in_specs = (P(None, word_axis), P(pair_axes, None))
    out_specs = (P(pair_axes, word_axis), P(pair_axes))
    fn = shard_map(
        functools.partial(_local_intersect, word_axis=word_axis, write_children=True),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def sharded_level_count_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
):
    """Count-only (k = k_max) level body: (bits, pairs) -> counts."""
    in_specs = (P(None, word_axis), P(pair_axes, None))
    out_specs = P(pair_axes)
    fn = shard_map(
        functools.partial(_local_intersect, word_axis=word_axis, write_children=False),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def make_sharded_intersect(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = None,
):
    """Drop-in ``intersect_fn`` for ``mine_preprocessed`` running on a mesh.

    Handles padding: pairs to equal per-shard blocks, words to the word-axis
    multiple. Returns numpy outputs stripped of padding.
    """
    pair_shards = int(np.prod([mesh.shape[a] for a in pair_axes]))
    word_shards = int(mesh.shape[word_axis]) if word_axis else 1
    write_fn, _, _ = sharded_level_step(mesh, pair_axes=pair_axes, word_axis=word_axis)
    count_fn, _, _ = sharded_level_count_step(mesh, pair_axes=pair_axes, word_axis=word_axis)

    def intersect_fn(bits: np.ndarray, pairs: np.ndarray, write_children: bool):
        m = pairs.shape[0]
        if m == 0:
            W = bits.shape[1]
            child = np.zeros((0, W), dtype=np.uint32) if write_children else None
            return child, np.zeros(0, dtype=np.int64)
        from .balance import balanced_blocks
        from ..kernels.intersect.ops import next_bucket

        padded_m, _ = balanced_blocks(next_bucket(m), pair_shards)
        pp = np.zeros((padded_m, 2), dtype=np.int32)
        pp[:m] = pairs
        bits_p = pad_words(np.ascontiguousarray(bits), word_shards)
        bits_j = jax.device_put(jnp.asarray(bits_p), NamedSharding(mesh, P(None, word_axis)))
        pairs_j = jax.device_put(jnp.asarray(pp), NamedSharding(mesh, P(pair_axes, None)))
        if write_children:
            child, counts = write_fn(bits_j, pairs_j)
            child_np = np.asarray(child)[:m, : bits.shape[1]]
            return child_np, np.asarray(counts)[:m].astype(np.int64)
        counts = count_fn(bits_j, pairs_j)
        return None, np.asarray(counts)[:m].astype(np.int64)

    return intersect_fn
