"""Distributed (SPMD) intersection step for the Kyiv miner.

The paper parallelises level k with shared-memory threads (§4.4.4): the
stored level is shared, candidate pairs are divided among threads, and no
inter-thread communication happens during a level. The SPMD mapping:

  * candidate **pairs** shard over the ``data`` (and ``pod``) mesh axes —
    exactly-equal padded blocks (see ``core.balance.balanced_blocks``);
  * the parent-level **bitset words** optionally shard over ``model``
    (row-parallelism for datasets whose bitset rows exceed one device);
    per-shard partial popcounts are ``psum``-ed over ``model`` — the only
    collective in the level body, mirroring the paper's
    "no inter-thread communication" property;
  * the parent table is replicated over the pair axes (the shared-memory
    analogue). For the count-only (k = k_max) step no child bitsets are
    written, so per-device HBM traffic is the two fetched rows per pair.

``make_sharded_pipeline`` returns a pipeline factory for
``mine_preprocessed(pipeline_factory=...)`` — the fused path: the parent
bitsets are device-put **once per level** (not once per batch), every batch
is dispatched asynchronously, and the per-pair classification (Alg. 1 lines
32-41) happens inside the shard_map body right after the popcount ``psum``,
so each device classifies its own pair shard with zero extra communication.
``make_sharded_intersect`` is the older drop-in ``intersect_fn`` (host
classification, device-put per batch) kept for compatibility — numerics of
both are identical to the sequential engines (tested on an 8-device CPU mesh
in ``tests/test_sharded_driver.py``).

``sharded_level_step``/``sharded_level_count_step`` (and their
``*_classify_*`` fused twins) are the jittable bodies the multi-pod dry-run
lowers on the production meshes (the paper-technique rows of the roofline
table).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..kernels.intersect.ops import BatchHandle, locality_order, next_bucket

__all__ = [
    "sharded_level_step",
    "sharded_level_count_step",
    "sharded_level_classify_step",
    "sharded_level_classify_count_step",
    "make_sharded_intersect",
    "make_sharded_pipeline",
    "ShardedLevelPipeline",
    "pad_words",
]


def pad_words(bits: np.ndarray, multiple: int) -> np.ndarray:
    """Pad the word dimension to a multiple (extra words are zero = no rows)."""
    t, w = bits.shape
    rem = (-w) % multiple
    if rem == 0:
        return bits
    return np.concatenate([bits, np.zeros((t, rem), dtype=bits.dtype)], axis=1)


def _local_intersect(bits_ref, pairs, *, word_axis: str | None, write_children: bool):
    a = jnp.take(bits_ref, pairs[:, 0], axis=0)
    b = jnp.take(bits_ref, pairs[:, 1], axis=0)
    child = jnp.bitwise_and(a, b)
    partial = jnp.sum(jax.lax.population_count(child).astype(jnp.int32), axis=1)
    counts = jax.lax.psum(partial, word_axis) if word_axis else partial
    if write_children:
        return child, counts
    return counts


def sharded_level_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
):
    """Build the write-variant level body: (bits, pairs) -> (child, counts).

    bits: (t, W) uint32, sharded P(None, word_axis);
    pairs: (M, 2) int32, sharded P(pair_axes, None);
    child: (M, W), sharded P(pair_axes, word_axis); counts: (M,) P(pair_axes).
    """
    in_specs = (P(None, word_axis), P(pair_axes, None))
    out_specs = (P(pair_axes, word_axis), P(pair_axes))
    fn = shard_map(
        functools.partial(_local_intersect, word_axis=word_axis, write_children=True),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def sharded_level_count_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
):
    """Count-only (k = k_max) level body: (bits, pairs) -> counts."""
    in_specs = (P(None, word_axis), P(pair_axes, None))
    out_specs = P(pair_axes)
    fn = shard_map(
        functools.partial(_local_intersect, word_axis=word_axis, write_children=False),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def _local_intersect_classify(
    bits_ref, pairs, minp, tau, *, word_axis: str | None, write_children: bool
):
    """Shard-local fused body: gather, AND, popcount(+psum), classify.

    ``minp`` is the per-pair min parent popcount (sharded with the pairs);
    classification runs after the word-axis ``psum`` so every pair shard
    classifies its own pairs from complete counts — still no inter-device
    communication beyond the popcount psum.
    """
    a = jnp.take(bits_ref, pairs[:, 0], axis=0)
    b = jnp.take(bits_ref, pairs[:, 1], axis=0)
    child = jnp.bitwise_and(a, b)
    partial = jnp.sum(jax.lax.population_count(child).astype(jnp.int32), axis=1)
    counts = jax.lax.psum(partial, word_axis) if word_axis else partial
    skip = (counts == 0) | (counts == minp)
    emit = jnp.logical_not(skip) & (counts <= tau)
    classes = jnp.where(skip, 0, jnp.where(emit, 1, 2)).astype(jnp.int32)
    if write_children:
        return child, counts, classes
    return counts, classes


def sharded_level_classify_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
):
    """Fused write-variant level body: (bits, pairs, minp, tau) ->
    (child, counts, classes)."""
    in_specs = (P(None, word_axis), P(pair_axes, None), P(pair_axes), P())
    out_specs = (P(pair_axes, word_axis), P(pair_axes), P(pair_axes))
    fn = shard_map(
        functools.partial(
            _local_intersect_classify, word_axis=word_axis, write_children=True
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def sharded_level_classify_count_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
):
    """Fused count-only (k = k_max) level body: (bits, pairs, minp, tau) ->
    (counts, classes)."""
    in_specs = (P(None, word_axis), P(pair_axes, None), P(pair_axes), P())
    out_specs = (P(pair_axes), P(pair_axes))
    fn = shard_map(
        functools.partial(
            _local_intersect_classify, word_axis=word_axis, write_children=False
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


class ShardedLevelPipeline:
    """Mesh-sharded analogue of ``repro.kernels.intersect.LevelPipeline``.

    The parent bitsets live on the mesh for the whole level; ``submit``
    ships only the (balanced, padded) pair shard list and the per-pair min
    parent counts, dispatches asynchronously, and classification comes back
    fused from the device. Padding pairs are ``(0, 0)`` self-pairs — uniform
    by construction, so the fused classifier marks them CLASS_SKIP and they
    are sliced away before the caller ever sees them.

    ``write_fn``/``count_fn`` are the jitted shard_map level bodies. Pass
    the pair built once by :func:`make_sharded_pipeline` so executables are
    reused across levels; constructing them here instead (``None``) would
    re-trace per level.
    """

    def __init__(
        self,
        mesh: Mesh,
        bits: np.ndarray,
        parent_counts: np.ndarray,
        tau: int,
        *,
        pair_axes: tuple[str, ...] = ("data",),
        word_axis: str | None = None,
        locality_sort: bool = True,
        fused_classify: bool = True,
        write_fn=None,
        count_fn=None,
    ):
        from .balance import balanced_blocks

        self._balanced_blocks = balanced_blocks
        self.mesh = mesh
        self.pair_axes = pair_axes
        self.word_axis = word_axis
        self.locality_sort = locality_sort
        self.fused_classify = fused_classify
        self.n_words = int(bits.shape[1])
        self.pair_shards = int(np.prod([mesh.shape[a] for a in pair_axes]))
        word_shards = int(mesh.shape[word_axis]) if word_axis else 1
        if write_fn is None or count_fn is None:
            write_fn, count_fn = _build_sharded_step_fns(
                mesh, pair_axes=pair_axes, word_axis=word_axis,
                fused_classify=fused_classify,
            )
        self._write_fn = write_fn
        self._count_fn = count_fn
        bits_p = pad_words(np.ascontiguousarray(bits), word_shards)
        # device-resident across every batch of the level
        self._bits = jax.device_put(
            jnp.asarray(bits_p), NamedSharding(mesh, P(None, word_axis))
        )
        self._pc = np.asarray(parent_counts, dtype=np.int32)
        self._tau = jnp.int32(tau)
        self._pairs_sharding = NamedSharding(mesh, P(pair_axes, None))
        self._minp_sharding = NamedSharding(mesh, P(pair_axes))

    def submit(self, pairs: np.ndarray, write_children: bool) -> BatchHandle:
        m = int(pairs.shape[0])
        if m == 0:
            child = np.zeros((0, self.n_words), dtype=np.uint32) if write_children else None
            classes = np.zeros(0, dtype=np.int32) if self.fused_classify else None
            out = (child, np.zeros(0, dtype=np.int64), classes)
            return BatchHandle(lambda: out)

        pairs = np.ascontiguousarray(pairs, dtype=np.int32)
        order = inverse = None
        if self.locality_sort:
            order, inverse = locality_order(pairs)
            if order is not None:
                pairs = pairs[order]

        padded_m, _ = self._balanced_blocks(next_bucket(m), self.pair_shards)
        pp = np.zeros((padded_m, 2), dtype=np.int32)
        pp[:m] = pairs
        pairs_j = jax.device_put(jnp.asarray(pp), self._pairs_sharding)

        cls_d = None
        if self.fused_classify:
            minp = np.zeros(padded_m, dtype=np.int32)
            minp[:m] = np.minimum(self._pc[pairs[:, 0]], self._pc[pairs[:, 1]])
            minp[m:] = self._pc[0]  # padding self-pairs: count == minp -> CLASS_SKIP
            minp_j = jax.device_put(jnp.asarray(minp), self._minp_sharding)
            if write_children:
                child_d, cnt_d, cls_d = self._write_fn(
                    self._bits, pairs_j, minp_j, self._tau
                )
            else:
                child_d = None
                cnt_d, cls_d = self._count_fn(self._bits, pairs_j, minp_j, self._tau)
        else:  # host-classified baseline: legacy (bits, pairs) step bodies
            if write_children:
                child_d, cnt_d = self._write_fn(self._bits, pairs_j)
            else:
                child_d = None
                cnt_d = self._count_fn(self._bits, pairs_j)

        n_words = self.n_words

        def materialize():
            counts = np.asarray(cnt_d)[:m].astype(np.int64)
            classes = np.asarray(cls_d)[:m].astype(np.int32) if cls_d is not None else None
            child = None
            if child_d is not None:
                child = np.asarray(child_d)[:m, :n_words]
            if inverse is not None:
                counts = counts[inverse]
                if classes is not None:
                    classes = classes[inverse]
                if child is not None:
                    child = child[inverse]
            return child, counts, classes

        return BatchHandle(materialize)


def _build_sharded_step_fns(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...],
    word_axis: str | None,
    fused_classify: bool,
):
    if fused_classify:
        write_fn, _, _ = sharded_level_classify_step(
            mesh, pair_axes=pair_axes, word_axis=word_axis
        )
        count_fn, _, _ = sharded_level_classify_count_step(
            mesh, pair_axes=pair_axes, word_axis=word_axis
        )
    else:
        write_fn, _, _ = sharded_level_step(
            mesh, pair_axes=pair_axes, word_axis=word_axis
        )
        count_fn, _, _ = sharded_level_count_step(
            mesh, pair_axes=pair_axes, word_axis=word_axis
        )
    return write_fn, count_fn


def make_sharded_pipeline(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = None,
    locality_sort: bool = True,
    fused_classify: bool = True,
):
    """Pipeline factory for ``mine_preprocessed(pipeline_factory=...)``.

    Returns ``factory(bits, parent_counts, tau) -> ShardedLevelPipeline``;
    compared to :func:`make_sharded_intersect` this keeps the level bitsets
    device-resident across batches and (with ``fused_classify=True``)
    returns fused device classification. The jitted shard_map bodies are
    built once here and shared by every level's pipeline, so XLA executables
    are reused across levels. ``fused_classify=False`` selects the legacy
    step bodies and host classification — the baseline path.
    """
    write_fn, count_fn = _build_sharded_step_fns(
        mesh, pair_axes=pair_axes, word_axis=word_axis, fused_classify=fused_classify
    )

    def factory(bits: np.ndarray, parent_counts: np.ndarray, tau: int):
        return ShardedLevelPipeline(
            mesh,
            bits,
            parent_counts,
            tau,
            pair_axes=pair_axes,
            word_axis=word_axis,
            locality_sort=locality_sort,
            fused_classify=fused_classify,
            write_fn=write_fn,
            count_fn=count_fn,
        )

    return factory


def make_sharded_intersect(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = None,
):
    """Drop-in ``intersect_fn`` for ``mine_preprocessed`` running on a mesh.

    Handles padding: pairs to equal per-shard blocks, words to the word-axis
    multiple. Returns numpy outputs stripped of padding.
    """
    pair_shards = int(np.prod([mesh.shape[a] for a in pair_axes]))
    word_shards = int(mesh.shape[word_axis]) if word_axis else 1
    write_fn, _, _ = sharded_level_step(mesh, pair_axes=pair_axes, word_axis=word_axis)
    count_fn, _, _ = sharded_level_count_step(mesh, pair_axes=pair_axes, word_axis=word_axis)

    def intersect_fn(bits: np.ndarray, pairs: np.ndarray, write_children: bool):
        m = pairs.shape[0]
        if m == 0:
            W = bits.shape[1]
            child = np.zeros((0, W), dtype=np.uint32) if write_children else None
            return child, np.zeros(0, dtype=np.int64)
        from .balance import balanced_blocks
        from ..kernels.intersect.ops import next_bucket

        padded_m, _ = balanced_blocks(next_bucket(m), pair_shards)
        pp = np.zeros((padded_m, 2), dtype=np.int32)
        pp[:m] = pairs
        bits_p = pad_words(np.ascontiguousarray(bits), word_shards)
        bits_j = jax.device_put(jnp.asarray(bits_p), NamedSharding(mesh, P(None, word_axis)))
        pairs_j = jax.device_put(jnp.asarray(pp), NamedSharding(mesh, P(pair_axes, None)))
        if write_children:
            child, counts = write_fn(bits_j, pairs_j)
            child_np = np.asarray(child)[:m, : bits.shape[1]]
            return child_np, np.asarray(counts)[:m].astype(np.int64)
        counts = count_fn(bits_j, pairs_j)
        return None, np.asarray(counts)[:m].astype(np.int64)

    return intersect_fn
