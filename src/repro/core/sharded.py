"""Distributed (SPMD) shard_map level bodies for the Kyiv miner.

The paper parallelises level k with shared-memory threads (§4.4.4): the
stored level is shared, candidate pairs are divided among threads, and no
inter-thread communication happens during a level. The SPMD mapping:

  * candidate **pairs** shard over the ``data`` (and ``pod``) mesh axes —
    exactly-equal padded blocks (see ``core.balance.balanced_blocks``);
  * the parent-level **bitset words** optionally shard over ``model``
    (row-parallelism for datasets whose bitset rows exceed one device);
    per-shard partial popcounts are ``psum``-ed over ``model`` — the only
    collective in the level body, mirroring the paper's
    "no inter-thread communication" property;
  * the parent table is replicated over the pair axes (the shared-memory
    analogue). For the count-only (k = k_max) step no child bitsets are
    written, so per-device HBM traffic is the two fetched rows per pair.

This module holds exactly the jittable ``shard_map`` bodies
(``sharded_level_step``/``sharded_level_count_step`` and their
``*_classify_*`` fused twins — what the multi-pod dry-run lowers on the
production meshes) plus two thin wrappers. All mesh residency, pair
bucketing and device-put plumbing that used to be duplicated here now lives
in ``repro.core.placement.MeshPlacement``: ``make_sharded_pipeline`` is a
pipeline factory for ``mine_preprocessed(pipeline_factory=...)`` binding a
``MeshPlacement`` into the generic ``LevelPipeline``, and
``make_sharded_intersect`` is the older drop-in ``intersect_fn`` contract
(host classification, placement per batch) kept for compatibility —
numerics of both are identical to the sequential engines (tested on an
8-device CPU mesh in ``tests/test_sharded_driver.py``).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = [
    "sharded_level_step",
    "sharded_level_count_step",
    "sharded_level_classify_step",
    "sharded_level_classify_count_step",
    "sharded_coverage_step",
    "sharded_frontier_support_step",
    "make_sharded_intersect",
    "make_sharded_pipeline",
    "pad_words",
]


def pad_words(bits: np.ndarray, multiple: int) -> np.ndarray:
    """Pad the word dimension to a multiple (extra words are zero = no rows)."""
    t, w = bits.shape
    rem = (-w) % multiple
    if rem == 0:
        return bits
    return np.concatenate([bits, np.zeros((t, rem), dtype=bits.dtype)], axis=1)


# Word axes may be a single ICI axis name ("model") or a tuple of axis
# names for hybrid DCN x ICI meshes (PartitionSpec and psum/all_gather both
# accept tuples, flattening major-to-minor in tuple order).
WordAxes = "str | tuple[str, ...] | None"


def _replicate_pairs_dim(x, pair_axes):
    """All-gather a pair-sharded per-pair vector back to the full batch.

    The tiled gather concatenates shards in flattened (major-to-minor)
    pair-axis index order — the same order ``P(pair_axes)`` splits them, so
    the result equals the out-spec reassembly but lands **replicated**:
    on a process-spanning mesh every host can read it without a
    cross-process transfer at materialization time.
    """
    return jax.lax.all_gather(x, pair_axes, axis=0, tiled=True)


def _local_intersect(
    bits_ref, pairs, *, word_axis, pair_axes, write_children: bool, replicate: bool
):
    a = jnp.take(bits_ref, pairs[:, 0], axis=0)
    b = jnp.take(bits_ref, pairs[:, 1], axis=0)
    child = jnp.bitwise_and(a, b)
    partial = jnp.sum(jax.lax.population_count(child).astype(jnp.int32), axis=1)
    counts = jax.lax.psum(partial, word_axis) if word_axis else partial
    if replicate:
        counts = _replicate_pairs_dim(counts, pair_axes)
    if write_children:
        return child, counts
    return counts


def sharded_level_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: "WordAxes" = "model",
    replicate: bool = False,
):
    """Build the write-variant level body: (bits, pairs) -> (child, counts).

    bits: (t, W) uint32, sharded P(None, word_axis);
    pairs: (M, 2) int32, sharded P(pair_axes, None);
    child: (M, W), sharded P(pair_axes, word_axis); counts: (M,) P(pair_axes).

    ``replicate=True`` is the process-spanning variant: counts come back
    replicated (out-spec ``P()``) via a tiled pair-axis all-gather, so a
    multi-host coordinator can materialize them host-side without touching
    non-addressable shards. Children stay pair/word sharded either way.
    """
    in_specs = (P(None, word_axis), P(pair_axes, None))
    out_specs = (P(pair_axes, word_axis), P() if replicate else P(pair_axes))
    fn = shard_map(
        functools.partial(
            _local_intersect,
            word_axis=word_axis,
            pair_axes=pair_axes,
            write_children=True,
            replicate=replicate,
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def sharded_level_count_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: "WordAxes" = "model",
    replicate: bool = False,
):
    """Count-only (k = k_max) level body: (bits, pairs) -> counts."""
    in_specs = (P(None, word_axis), P(pair_axes, None))
    out_specs = P() if replicate else P(pair_axes)
    fn = shard_map(
        functools.partial(
            _local_intersect,
            word_axis=word_axis,
            pair_axes=pair_axes,
            write_children=False,
            replicate=replicate,
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def _local_intersect_classify(
    bits_ref,
    pairs,
    minp,
    tau,
    *,
    word_axis,
    pair_axes,
    write_children: bool,
    replicate: bool,
):
    """Shard-local fused body: gather, AND, popcount(+psum), classify.

    ``minp`` is the per-pair min parent popcount (sharded with the pairs);
    classification runs after the word-axis ``psum`` so every pair shard
    classifies its own pairs from complete counts — still no inter-device
    communication beyond the popcount psum (plus, in the process-spanning
    ``replicate`` variant, the pair-axis all-gather of the per-pair outputs).
    """
    a = jnp.take(bits_ref, pairs[:, 0], axis=0)
    b = jnp.take(bits_ref, pairs[:, 1], axis=0)
    child = jnp.bitwise_and(a, b)
    partial = jnp.sum(jax.lax.population_count(child).astype(jnp.int32), axis=1)
    counts = jax.lax.psum(partial, word_axis) if word_axis else partial
    skip = (counts == 0) | (counts == minp)
    emit = jnp.logical_not(skip) & (counts <= tau)
    classes = jnp.where(skip, 0, jnp.where(emit, 1, 2)).astype(jnp.int32)
    if replicate:
        counts = _replicate_pairs_dim(counts, pair_axes)
        classes = _replicate_pairs_dim(classes, pair_axes)
    if write_children:
        return child, counts, classes
    return counts, classes


def sharded_level_classify_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: "WordAxes" = "model",
    replicate: bool = False,
):
    """Fused write-variant level body: (bits, pairs, minp, tau) ->
    (child, counts, classes)."""
    in_specs = (P(None, word_axis), P(pair_axes, None), P(pair_axes), P())
    per_pair = P() if replicate else P(pair_axes)
    out_specs = (P(pair_axes, word_axis), per_pair, per_pair)
    fn = shard_map(
        functools.partial(
            _local_intersect_classify,
            word_axis=word_axis,
            pair_axes=pair_axes,
            write_children=True,
            replicate=replicate,
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def sharded_level_classify_count_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: "WordAxes" = "model",
    replicate: bool = False,
):
    """Fused count-only (k = k_max) level body: (bits, pairs, minp, tau) ->
    (counts, classes)."""
    in_specs = (P(None, word_axis), P(pair_axes, None), P(pair_axes), P())
    per_pair = P() if replicate else P(pair_axes)
    out_specs = (per_pair, per_pair)
    fn = shard_map(
        functools.partial(
            _local_intersect_classify,
            word_axis=word_axis,
            pair_axes=pair_axes,
            write_children=False,
            replicate=replicate,
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def _local_coverage(bits_ref, sets, weights, *, pair_axes, n_set_items):
    """Shard-local coverage body (``kernels.coverage`` semantics): K-way AND
    over locally-held bitset words, bit-plane accumulation weighted per set,
    then a psum over the pair axes — words stay sharded, the set axis is
    reduced away, so the only collective is the accumulator psum (the
    record-coverage analogue of the level body's popcount psum)."""
    mask = jnp.take(bits_ref, sets[:, 0], axis=0)
    for t in range(1, n_set_items):
        mask = jnp.bitwise_and(mask, jnp.take(bits_ref, sets[:, t], axis=0))
    wt = weights.astype(jnp.int32)[:, None]
    rows = []
    for b in range(32):
        sel = (jnp.right_shift(mask, jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.int32)
        rows.append(jnp.sum(sel * wt, axis=0))
    acc = jnp.stack(rows, axis=0)
    return jax.lax.psum(acc, pair_axes)


def sharded_coverage_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = "model",
    n_set_items: int = 3,
):
    """Record-coverage body: (bits, sets, weights) -> acc (32, W).

    bits: (t, W) uint32, sharded P(None, word_axis);
    sets: (M, n_set_items) int32, sharded P(pair_axes, None);
    weights: (M,) int32, sharded P(pair_axes);
    acc: (32, W) int32, sharded P(None, word_axis) — replicated over pairs.
    """
    in_specs = (P(None, word_axis), P(pair_axes, None), P(pair_axes))
    out_specs = P(None, word_axis)
    fn = shard_map(
        functools.partial(
            _local_coverage, pair_axes=pair_axes, n_set_items=n_set_items
        ),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return jax.jit(fn), in_specs, out_specs


def sharded_frontier_support_step(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    k: int = 2,
    t_pad: int = 16,
    bits: int = 1,
    ipw: int = 1,
    replicate: bool = False,
):
    """Frontier support-test body, sharded over the pair axes:
    (ids, keys, pairs, valid) -> ok.

    ids: (t_pad, k) int32 and keys: (t_pad, w) int32, replicated P(None,
    None) — the parent id table and packed sorted key table are the shared
    (read-only) side, mirroring the level bodies' replicated bitsets;
    pairs: (M, 2) int32 sharded P(pair_axes, None); valid: (M,) bool
    P(pair_axes); ok: (M,) bool P(pair_axes). Each pair shard binary-searches
    its own candidates' prefix-drop subsets — no collective at all (the
    paper's "no inter-thread communication" §4.4.4 holds exactly here).
    ``replicate=True`` (process-spanning meshes) all-gathers ``ok`` back to
    the full batch so every host can partition it locally.
    """
    from ..kernels.frontier.frontier import support_ok_body

    in_specs = (P(None, None), P(None, None), P(pair_axes, None), P(pair_axes))
    out_specs = P() if replicate else P(pair_axes)

    def body(ids, keys, pairs, valid):
        ok = support_ok_body(
            ids, keys, pairs, valid, k=k, t_pad=t_pad, bits=bits, ipw=ipw
        )
        return _replicate_pairs_dim(ok, pair_axes) if replicate else ok

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return jax.jit(fn), in_specs, out_specs


def make_sharded_pipeline(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = None,
    locality_sort: bool = True,
    fused_classify: bool = True,
):
    """Pipeline factory for ``mine_preprocessed(pipeline_factory=...)``.

    Returns ``factory(bits, parent_counts, tau) -> LevelPipeline`` bound to
    one ``MeshPlacement``: level bitsets stay mesh-resident across batches,
    (with ``fused_classify=True``) classification comes back fused from the
    shard_map body, and the jitted step executables are shared across levels
    and placements of the same mesh through ``ops.EXEC_CACHE``.
    ``fused_classify=False`` selects the legacy step bodies and host
    classification — the baseline path.
    """
    from ..kernels.intersect.ops import LevelPipeline
    from .placement import MeshPlacement

    placement = MeshPlacement(mesh, pair_axes=pair_axes, word_axis=word_axis)

    def factory(bits: np.ndarray, parent_counts: np.ndarray, tau: int):
        return LevelPipeline(
            bits,
            parent_counts,
            tau=tau,
            placement=placement,
            fused_classify=fused_classify,
            locality_sort=locality_sort,
        )

    return factory


def make_sharded_intersect(
    mesh: Mesh,
    *,
    pair_axes: tuple[str, ...] = ("data",),
    word_axis: str | None = None,
):
    """Drop-in ``intersect_fn`` for ``mine_preprocessed`` running on a mesh.

    The pre-pipeline injection contract: classification stays on the host
    and the bitsets are re-placed per batch (one fresh ``LevelPipeline``
    each call). Kept for compatibility; new code should prefer
    :func:`make_sharded_pipeline`.
    """
    from ..kernels.intersect.ops import LevelPipeline
    from .placement import MeshPlacement

    placement = MeshPlacement(mesh, pair_axes=pair_axes, word_axis=word_axis)

    def intersect_fn(bits: np.ndarray, pairs: np.ndarray, write_children: bool):
        pipe = LevelPipeline(
            bits,
            np.zeros(bits.shape[0], dtype=np.int64),
            tau=0,
            placement=placement,
            fused_classify=False,
        )
        child, counts, _ = pipe.submit(pairs, write_children).result()
        return child, counts

    return intersect_fn
