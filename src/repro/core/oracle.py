"""Brute-force oracle for minimal τ-infrequent itemsets (Definition 3.7).

Enumerates every itemset of ``I_A`` up to ``k_max`` and checks τ-infrequency
and minimality directly from row sets. Exponential — for tests on tiny
datasets only. This is the ground truth the Kyiv driver, the sharded driver
and the MINIT baseline are all validated against.
"""

from __future__ import annotations

import itertools

import numpy as np

from .items import ItemTable, itemize

__all__ = ["brute_force_minimal_infrequent"]


def brute_force_minimal_infrequent(
    dataset: np.ndarray, tau: int, kmax: int
) -> set[tuple[int, ...]]:
    table = itemize(dataset)
    n_items = table.n_items
    rows = [frozenset(table.rows_of(i).tolist()) for i in range(n_items)]

    def freq(itemset: tuple[int, ...]) -> int:
        r = rows[itemset[0]]
        for it in itemset[1:]:
            r = r & rows[it]
        return len(r)

    found: set[tuple[int, ...]] = set()
    for k in range(1, kmax + 1):
        for combo in itertools.combinations(range(n_items), k):
            # items must come from distinct columns to co-occur meaningfully;
            # same-column distinct values have empty intersection -> freq 0,
            # but |R_S| = 0 <= tau would make them "infrequent". Def. 3.7 does
            # not exclude them, but such sets have an empty-row subset chain;
            # the paper's Alg. 1 line 32 explicitly skips absent itemsets, so
            # the reference excludes freq-0 sets as well.
            f = freq(combo)
            if f == 0 or f > tau:
                continue
            minimal = True
            if k > 1:
                for sub in itertools.combinations(combo, k - 1):
                    if freq(sub) <= tau:
                        minimal = False
                        break
            if minimal:
                found.add(combo)
    return found
