"""Count-only frequency bounds: Lemma 4.6 (line 27) and Corollary 4.7 (line 29).

Both tests prove "W is **not** τ-infrequent" from already-stored counts, so
at the last level (k = k_max) they remove row intersections entirely for the
pruned pairs. On TPU the saving is structural: pruned pairs never enter the
intersection kernel's pair list, and the survivors use the *count-only* kernel
variant that never writes child bitsets back to HBM.

Notation for a candidate W = [p_1..p_{k-2}, a, b] joined from
I = [p.., a] and J = [p.., b] (both level k-1 rows):

* line 27 (direct Lemma 4.6 with I' = prefix):
    prune if |R_I| + |R_J| > |R_prefix| + τ
  where |R_prefix| comes from level k-2 (|R_∅| = n when k = 2).

* line 29 (Corollary 4.7) with c = p_{k-2} (k >= 3):
    Γ0 = |R_{prefix\\c + a + b}|   (level k-1 count — a support subset of W,
                                    guaranteed present after line 23)
    Γ1 = |R_{prefix\\c + a}| − |R_I|    (level k-2 count − level k-1 count)
    Γ2 = |R_{prefix\\c + b}| − |R_J|
    prune if Γ0 > min(Γ1, Γ2) + τ
"""

from __future__ import annotations

import numpy as np

from .prefix import CandidateBatch, Level
from .support import ItemsetIndex

__all__ = ["lemma_bound", "corollary_bound", "apply_bounds"]


def lemma_bound(
    cand: CandidateBatch,
    level: Level,
    grandparent_index: ItemsetIndex | None,
    n_rows: int,
    tau: int,
) -> np.ndarray:
    """True where Alg. 1 line 27 prunes the pair (W proven not τ-infrequent)."""
    m, kp1 = cand.itemsets.shape
    if m == 0:
        return np.zeros(0, dtype=bool)
    ci = level.counts[cand.i_idx]
    cj = level.counts[cand.j_idx]
    if kp1 == 2:
        prefix_counts = np.full(m, n_rows, dtype=np.int64)  # |R_∅| = n
    else:
        assert grandparent_index is not None
        prefix = cand.itemsets[:, : kp1 - 2]
        prefix_counts = grandparent_index.lookup_counts(prefix)
        # prefix of a stored I is itself stored (BFS invariant); assert in debug.
        if (prefix_counts < 0).any():  # pragma: no cover - invariant guard
            raise AssertionError("BFS invariant violated: stored itemset with unstored prefix")
    return ci + cj > prefix_counts + tau


def corollary_bound(
    cand: CandidateBatch,
    level: Level,
    level_index: ItemsetIndex,
    grandparent_index: ItemsetIndex | None,
    tau: int,
) -> np.ndarray:
    """True where Alg. 1 line 29 prunes the pair. Requires k+1 >= 3."""
    m, kp1 = cand.itemsets.shape
    if m == 0 or kp1 < 3:
        return np.zeros(m, dtype=bool)
    assert grandparent_index is not None or kp1 == 3
    its = cand.itemsets
    # W = [p_1..p_{k-2}, a, b]; c = p_{k-2} is column kp1-3.
    keep = np.ones(kp1, dtype=bool)
    keep[kp1 - 3] = False
    wo_c = its[:, keep]  # [p_1..p_{k-3}, a, b]
    gamma0 = level_index.lookup_counts(wo_c)
    if (gamma0 < 0).any():  # support test ran first; subsets must be present
        raise AssertionError("corollary_bound called before support_test filtered candidates")

    ci = level.counts[cand.i_idx]
    cj = level.counts[cand.j_idx]
    wo_c_a = wo_c[:, :-1]  # [p_1..p_{k-3}, a]
    wo_c_b = np.concatenate([wo_c[:, :-2], wo_c[:, -1:]], axis=1)  # [p_1.., b]
    if kp1 == 3:
        # prefix\c is empty: the (k-2)-sets are singletons {a}, {b} = level-1.
        assert grandparent_index is not None, "need singleton index for k=3"
    cnt_wo_c_a = grandparent_index.lookup_counts(wo_c_a)
    cnt_wo_c_b = grandparent_index.lookup_counts(wo_c_b)
    if (cnt_wo_c_a < 0).any() or (cnt_wo_c_b < 0).any():
        raise AssertionError("BFS invariant violated in corollary lookup")
    g1 = cnt_wo_c_a - ci
    g2 = cnt_wo_c_b - cj
    return gamma0 > np.minimum(g1, g2) + tau


def apply_bounds(
    cand: CandidateBatch,
    level: Level,
    level_index: ItemsetIndex,
    grandparent_index: ItemsetIndex | None,
    n_rows: int,
    tau: int,
) -> np.ndarray:
    """Combined line 27 + line 29 prune mask (True = prune, skip intersection)."""
    pruned = lemma_bound(cand, level, grandparent_index, n_rows, tau)
    if cand.itemsets.shape[1] >= 3:
        alive = ~pruned
        if alive.any():
            sub = CandidateBatch(
                i_idx=cand.i_idx[alive], j_idx=cand.j_idx[alive], itemsets=cand.itemsets[alive]
            )
            cor = corollary_bound(sub, level, level_index, grandparent_index, tau)
            idx = np.nonzero(alive)[0]
            pruned[idx[cor]] = True
    return pruned
