"""Multi-host fleet placement: lockstep mining over process-sharded bitsets.

The CPU backend cannot run cross-process XLA programs, and even where it
can, the paper's level body needs exactly one collective — the popcount sum
over the word axis (§4.4.4's "no inter-thread communication" property holds
within a host; across hosts one all-reduce per batch is the irreducible
minimum). :class:`FleetPlacement` therefore runs the whole BFS **lockstep**:
every process executes the identical mining loop over its *local* word
stripes (a ``DatasetStore`` built with ``shard=(pid, nproc)``) through an
ordinary inner placement (host numpy, one device, or an in-host mesh), and
the only cross-host traffic is

* one ``allreduce_sum`` of each batch's partial popcounts over the DCN axis
  (``repro.core.collective``), after which classification runs host-side on
  the now-global counts, and
* the row-set-grouping rendezvous in ``core.preprocess`` (local hashes are
  combined globally so duplicate detection agrees everywhere).

Everything after the global counts — candidate generation, support tests,
bound pruning, emission order — is a deterministic function of global
metadata (itemsets, counts, frequencies), so every process walks the exact
same levels and emits bit-identical results without further communication.
That lockstep determinism is also why batch sizing must be process-invariant:
the sharded store pads the global word axis to ``word_tile * nproc`` so all
local widths are equal.

The fleet deliberately reports ``use_device_frontier = False`` — frontier
transitions run the host reference path (``core.frontier._advance_host``),
whose candidate pipeline reads only global host mirrors.
"""

from __future__ import annotations

import numpy as np

from ..kernels.intersect import ops as _ops
from ..obs import metrics as _om
from .collective import Collective, LoopbackCollective
from .placement import _count_dispatch

__all__ = ["FleetPlacement"]

_FLEET_REDUCES = _om.counter(
    "repro_fleet_allreduce_total",
    "Cross-host count all-reduces by mining seam.",
    ("site",),
)


class FleetPlacement:
    """Wrap an inner single-process placement into a multi-process fleet.

    ``inner`` executes every batch against the process-local word stripes;
    this wrapper all-reduces the resulting partial popcounts through
    ``collective`` and classifies on the global counts. With the default
    :class:`~repro.core.collective.LoopbackCollective` (one process) the
    reduction is the identity — the loopback fleet is bit-identical to the
    inner placement by construction, which is what the parity tests pin.
    """

    kind = "fleet"
    # frontier transitions must stay on the host reference path: candidate
    # generation there reads only global host mirrors (see module docstring)
    use_device_frontier = False

    def __init__(
        self,
        inner,
        *,
        collective: Collective | None = None,
        shard: tuple[int, int] | None = None,
    ):
        if getattr(inner, "kind", None) == "fleet":
            raise ValueError("fleet placements do not nest")
        self.inner = inner
        self.collective = collective if collective is not None else LoopbackCollective()
        self.shard = (
            tuple(shard)
            if shard is not None
            else (self.collective.pid, self.collective.nproc)
        )
        if self.shard != (self.collective.pid, self.collective.nproc):
            raise ValueError(
                f"shard {self.shard} disagrees with collective "
                f"({self.collective.pid}, {self.collective.nproc})"
            )
        self.store_word_tile = int(getattr(inner, "store_word_tile", 1) or 1)

    # -- mining levels -------------------------------------------------------

    def prepare(self, bits, parent_counts, tau: int, *, fused_classify: bool):
        # the inner placement counts only (fused_classify=False): its local
        # class codes would be wrong — classification must wait for the
        # global counts, so it happens host-side after the all-reduce
        pc = np.asarray(parent_counts, dtype=np.int64)
        inner_state = self.inner.prepare(bits, pc, tau, fused_classify=False)
        return (inner_state, pc, int(tau), bool(fused_classify))

    def padded_size(self, m: int, *, pad_buckets: bool = True) -> int:
        return self.inner.padded_size(m, pad_buckets=pad_buckets)

    def warm_buckets(
        self, n_words: int, *, fused: bool, write_children: bool
    ) -> tuple[int, ...]:
        # inner executables are non-fused regardless of the mining config
        return self.inner.warm_buckets(n_words, fused=False, write_children=write_children)

    def dispatch(self, state, padded_pairs, write_children: bool):
        _count_dispatch("dispatch", "fleet")
        inner_state, pc, tau, fused = state
        child, local_counts, _ = self.inner.dispatch(
            inner_state, padded_pairs, write_children
        )
        local = np.asarray(local_counts).astype(np.int64, copy=False)
        counts = self.collective.allreduce_sum(local)
        _FLEET_REDUCES.inc(site="dispatch")
        classes = None
        if fused:
            pairs = np.asarray(padded_pairs)
            minp = np.minimum(pc[pairs[:, 0]], pc[pairs[:, 1]])
            classes = _ops.classify_counts_host(counts, minp, tau)
        return child, counts, classes

    def put_bits(self, bits):
        return self.inner.put_bits(bits)

    # -- coverage (privacy risk engine) --------------------------------------

    def prepare_coverage(self, bits):
        return self.inner.prepare_coverage(bits)

    def coverage_dispatch(self, state, padded_sets, padded_weights):
        # the accumulator stays local-width; ``CoverageEngine`` sums batches
        # host-side and the fleet reduction happens once per query in
        # :meth:`record_counts_from_acc` — one collective per arity, not per
        # batch
        return self.inner.coverage_dispatch(state, padded_sets, padded_weights)

    def record_counts_from_acc(
        self, acc: np.ndarray, n_rows: int, word_map: np.ndarray | None = None
    ) -> np.ndarray:
        """Global per-record coverage counts from a *local* ``(32, W_local)``
        accumulator: scatter local records to their global row positions via
        the store's ``word_map``, then all-reduce. The risk engine calls this
        through ``getattr`` — non-fleet placements keep the plain
        ``acc_to_record_counts`` path. ``word_map=None`` means the local
        width *is* the global width (loopback fleet / unsharded store)."""
        acc = np.asarray(acc)
        if word_map is None:
            word_map = np.arange(acc.shape[1], dtype=np.int64)
        word_map = np.asarray(word_map, dtype=np.int64)
        w_local = acc.shape[1]
        local = acc.T.astype(np.int64)  # (W_local, 32) in local record order
        # size the global scatter by the ROW count, not by this process's
        # highest owned stripe — stripe ownership is round-robin, so the max
        # owned index differs per process and the all-reduce needs one shape
        n_global_words = (int(n_rows) + 31) // 32
        if w_local:
            n_global_words = max(n_global_words, int(word_map.max()) + 1)
        full = np.zeros((n_global_words, 32), dtype=np.int64)
        full[word_map[:w_local]] = local
        counts = self.collective.allreduce_sum(full.reshape(-1)[:n_rows])
        _FLEET_REDUCES.inc(site="coverage")
        return counts

    # -- frontier (never exercised: use_device_frontier is False, and
    # mine_levels routes non-host kinds through its host reference) ----------

    def prepare_frontier(self, itemsets, counts, n_symbols: int):
        return self.inner.prepare_frontier(itemsets, counts, n_symbols)

    def frontier_dispatch(self, state, lo: int, hi: int, n_pairs: int):
        return self.inner.frontier_dispatch(state, lo, hi, n_pairs)

    def frontier_mask(self, state, pairs, ok):
        return self.inner.frontier_mask(state, pairs, ok)

    def frontier_partition(self, classes):
        return self.inner.frontier_partition(classes)

    def release(self, state) -> None:
        if isinstance(state, tuple) and len(state) == 4:
            self.inner.release(state[0])
        else:
            self.inner.release(state)

    def describe(self) -> dict:
        return {
            "kind": self.kind,
            "shard": list(self.shard),
            "inner": self.inner.describe(),
            "collective": self.collective.stats(),
        }

    def __repr__(self) -> str:
        return f"FleetPlacement(shard={self.shard}, inner={self.inner!r})"
