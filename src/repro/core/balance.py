"""Work balancing (paper §4.4.4, Example 4.10).

The paper estimates the work of a parallel unit by its number of row
intersections (pairs) and packs units greedily into the least-loaded thread
(the ``T``-array; leftmost cell on ties). :func:`greedy_assign` reproduces
this exactly — Example 4.10 (``T={4,3,3}`` at k=2 and ``T={6,3,1}`` at k=3)
is a golden test.

For the SPMD (shard_map) driver the greedy scheme is superseded by
:func:`balanced_blocks`: candidate pairs are *flat* after vectorised
generation, so we can partition them into exactly-equal padded blocks — every
shard performs the same number of intersections, which is the strongest form
of the paper's balance property and is what a single-program mesh needs.
"""

from __future__ import annotations

import numpy as np

__all__ = ["greedy_assign", "pair_work_per_unit", "balanced_blocks"]


def greedy_assign(work: np.ndarray, n_workers: int) -> tuple[np.ndarray, np.ndarray]:
    """Greedy least-loaded assignment (leftmost tie-break), the paper's T-array.

    Args:
      work: (u,) work estimate per unit, in level order.
      n_workers: thread count t.
    Returns:
      (assignment (u,) worker index per unit, loads (n_workers,)).
    """
    loads = np.zeros(n_workers, dtype=np.int64)
    assignment = np.zeros(len(work), dtype=np.int64)
    for u, w in enumerate(np.asarray(work, dtype=np.int64)):
        cell = int(np.argmin(loads))  # argmin returns leftmost minimum
        assignment[u] = cell
        loads[cell] += w
    return assignment, loads


def pair_work_per_unit(itemsets: np.ndarray, unit: str = "auto") -> np.ndarray:
    """Work units for one level transition, per §4.4.4.

    ``unit="vertex"``: one unit per stored itemset I, work = its pair count
    (number of following itemsets in its prefix group) — the k=2 case of
    Example 4.10. ``unit="group"``: one unit per prefix group, work =
    ``g*(g-1)/2`` — the k>=3 case. ``auto`` picks vertex for k==1 levels
    (joining to k=2) and group otherwise, matching the paper's example.
    """
    from .prefix import prefix_group_sizes

    t, k = itemsets.shape
    sizes = prefix_group_sizes(itemsets) if t else np.zeros(0, dtype=np.int64)
    if unit == "auto":
        unit = "vertex" if k == 1 else "group"
    if unit == "vertex":
        starts = np.zeros(len(sizes), dtype=np.int64)
        if len(sizes):
            starts[1:] = np.cumsum(sizes)[:-1]
        group_id = np.repeat(np.arange(len(sizes)), sizes)
        local = np.arange(t, dtype=np.int64) - starts[group_id]
        return sizes[group_id] - 1 - local
    if unit == "group":
        return sizes * (sizes - 1) // 2
    raise ValueError(f"unknown unit {unit!r}")


def balanced_blocks(m: int, n_shards: int) -> tuple[int, int]:
    """Exact SPMD partition: pad ``m`` pairs to ``n_shards`` equal blocks.

    Returns (padded_m, block). Every shard gets ``block`` pairs; padding pairs
    are (0, 0) self-intersections whose results are discarded by the caller.
    """
    block = (m + n_shards - 1) // n_shards
    return block * n_shards, block
