"""Itemization of a categorical dataset (paper §3, Definitions 3.1-3.5).

A dataset ``A`` is an ``(n, m)`` integer matrix. An *item* is a pair
``(value, column)`` together with the set of rows ``R_a`` in which it occurs
(Definition 3.1). On TPU the row set is represented as a *bitset row*:
``uint32[W]`` with ``W = ceil(n / 32)`` words, so that the paper's
row-intersection bottleneck (Algorithm 1, line 31) becomes a bitwise AND +
population count — the representation the Pallas kernel in
``repro.kernels.intersect`` operates on.

The item table is column-ordered: items are produced column by column, value
by value, and assigned dense integer ids.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bitops import popcount_rows

__all__ = [
    "ItemTable",
    "itemize",
    "pack_rows_to_bits",
    "bits_popcount",
    "bits_to_rows",
    "WORD_BITS",
]

WORD_BITS = 32


def pack_rows_to_bits(row_sets: list[np.ndarray], n_rows: int, n_words: int | None = None) -> np.ndarray:
    """Pack a list of row-index arrays into a (len, W) uint32 bitset matrix."""
    if n_words is None:
        n_words = (n_rows + WORD_BITS - 1) // WORD_BITS
    bits = np.zeros((len(row_sets), n_words), dtype=np.uint32)
    for i, rows in enumerate(row_sets):
        if len(rows) == 0:
            continue
        w = rows // WORD_BITS
        b = rows % WORD_BITS
        np.bitwise_or.at(bits[i], w, np.uint32(1) << b.astype(np.uint32))
    return bits


def bits_popcount(bits: np.ndarray) -> np.ndarray:
    """Per-row population count of a (t, W) uint32 bitset matrix."""
    return popcount_rows(bits)


def bits_to_rows(bits_row: np.ndarray) -> np.ndarray:
    """Expand one bitset row back into sorted row indices.

    Vectorised: the words are forced little-endian and unpacked bit-by-bit,
    so bit ``b`` of word ``w`` lands at index ``w * 32 + b`` exactly —
    previously a per-word Python loop, now one ``np.unpackbits``.
    """
    words = np.ascontiguousarray(np.asarray(bits_row, dtype=np.uint32)).astype("<u4")
    unpacked = np.unpackbits(words.view(np.uint8), bitorder="little")
    return np.nonzero(unpacked)[0].astype(np.int64)


@dataclasses.dataclass
class ItemTable:
    """All items of a dataset (the paper's ``I_A``) in bitset form.

    Attributes:
      n_rows, n_cols: dataset dimensions.
      n_words: bitset width ``W``.
      value: (n_items,) original value of each item.
      col: (n_items,) column index ``j_a``.
      freq: (n_items,) ``|R_a|``.
      min_row: (n_items,) ``min R_a`` (used by the ascending order, Def. 4.5).
      bits: (n_items, W) uint32 bitset rows.
    """

    n_rows: int
    n_cols: int
    n_words: int
    value: np.ndarray
    col: np.ndarray
    freq: np.ndarray
    min_row: np.ndarray
    bits: np.ndarray

    @property
    def n_items(self) -> int:
        return int(self.value.shape[0])

    def rows_of(self, item: int) -> np.ndarray:
        return bits_to_rows(self.bits[item])

    def describe(self, item: int) -> tuple[int, int]:
        """(value, column) — 1-based column in paper notation is col+1."""
        return int(self.value[item]), int(self.col[item])

    def to_dataset(self) -> np.ndarray:
        """Reconstruct the (n_rows, n_cols) dataset from the item bitsets.

        Every cell belongs to exactly one item by construction, so scattering
        each item's value over its row set rebuilds the table — what lets the
        resident service (which keeps only the itemized form) hand a raw
        table to the anonymization planner.
        """
        out = np.zeros((self.n_rows, self.n_cols), dtype=np.int64)
        for i in range(self.n_items):
            rows = bits_to_rows(self.bits[i])
            out[rows[rows < self.n_rows], self.col[i]] = self.value[i]
        return out


def itemize(dataset: np.ndarray) -> ItemTable:
    """Build the item table ``I_A`` of an (n, m) integer dataset.

    Items are emitted column-major, values ascending within a column — a
    deterministic dense id assignment. Vectorised per column via np.unique.
    """
    dataset = np.asarray(dataset)
    if dataset.ndim != 2:
        raise ValueError(f"dataset must be 2-D, got shape {dataset.shape}")
    n, m = dataset.shape
    n_words = (n + WORD_BITS - 1) // WORD_BITS

    values, cols, freqs, min_rows, bit_blocks = [], [], [], [], []
    row_idx = np.arange(n, dtype=np.int64)
    for j in range(m):
        colv = dataset[:, j]
        uniq, inverse, counts = np.unique(colv, return_inverse=True, return_counts=True)
        k = len(uniq)
        # min row per item: first occurrence when scanning rows ascending.
        order = np.argsort(inverse, kind="stable")
        starts = np.zeros(k, dtype=np.int64)
        starts[1:] = np.cumsum(counts)[:-1]
        first_rows = row_idx[order][starts]
        # bitset: scatter each row's bit into its item's row.
        bits = np.zeros((k, n_words), dtype=np.uint32)
        w = row_idx // WORD_BITS
        b = (row_idx % WORD_BITS).astype(np.uint32)
        np.bitwise_or.at(bits, (inverse, w), np.uint32(1) << b)
        values.append(uniq.astype(np.int64))
        cols.append(np.full(k, j, dtype=np.int64))
        freqs.append(counts.astype(np.int64))
        min_rows.append(first_rows)
        bit_blocks.append(bits)

    return ItemTable(
        n_rows=n,
        n_cols=m,
        n_words=n_words,
        value=np.concatenate(values),
        col=np.concatenate(cols),
        freq=np.concatenate(freqs),
        min_row=np.concatenate(min_rows),
        bits=np.concatenate(bit_blocks, axis=0),
    )
