"""Host-side popcount with a guarded ``np.bitwise_count`` fallback.

``np.bitwise_count`` landed in numpy 2.0. The fast engines and the item
table pipeline all popcount uint bitset words on the host; on numpy<2.0 that
used to raise ``AttributeError`` mid-mine. Here the 2.0 ufunc is used when
present and an ``unpackbits``-based fallback (exact, just slower) otherwise,
so the numpy engine degrades gracefully instead of crashing.
"""

from __future__ import annotations

import numpy as np

__all__ = ["HAVE_BITWISE_COUNT", "popcount", "popcount_rows"]

HAVE_BITWISE_COUNT = hasattr(np, "bitwise_count")


def popcount_unpackbits(words: np.ndarray) -> np.ndarray:
    """Elementwise popcount via uint8 view + unpackbits (numpy<2.0 fallback)."""
    words = np.ascontiguousarray(words)
    nbytes = words.dtype.itemsize
    u8 = words.view(np.uint8).reshape(words.shape + (nbytes,))
    return np.unpackbits(u8, axis=-1).sum(axis=-1, dtype=np.uint8)


if HAVE_BITWISE_COUNT:

    def popcount(words: np.ndarray) -> np.ndarray:
        """Elementwise population count of an unsigned integer array."""
        return np.bitwise_count(words)

else:
    popcount = popcount_unpackbits


def popcount_rows(bits: np.ndarray) -> np.ndarray:
    """Per-row popcount of a (..., W) bitset matrix, summed over words (int64)."""
    return popcount(bits).sum(axis=-1).astype(np.int64)
