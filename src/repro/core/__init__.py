"""The paper's primary contribution: the Kyiv breadth-first minimal
τ-infrequent itemset miner (Demchuk & Leith 2014), in bitset/TPU form, plus
the MINIT baseline and a brute-force oracle."""

from . import exec_cache
from .items import ItemTable, itemize, pack_rows_to_bits, bits_popcount, bits_to_rows
from .placement import (
    BitsetPlacement,
    DevicePlacement,
    HostPlacement,
    MeshPlacement,
    make_placement,
    resolve_placement,
)
from .preprocess import Preprocessed, preprocess, ORDERINGS
from .prefix import (
    Level,
    CandidateBatch,
    generate_candidates,
    group_reps,
    iter_group_spans,
    prefix_group_sizes,
)
from .support import ItemsetIndex, support_test
from .bounds import lemma_bound, corollary_bound, apply_bounds
from .frontier import LevelFrontier, mine_levels
from .kyiv import (
    KyivConfig,
    LevelStats,
    MiningResult,
    MiningState,
    mine,
    mine_preprocessed,
    prepare,
)
from .oracle import brute_force_minimal_infrequent
from .minit import minit_minimal_infrequent

__all__ = [
    "exec_cache",
    "ItemTable",
    "itemize",
    "pack_rows_to_bits",
    "bits_popcount",
    "bits_to_rows",
    "BitsetPlacement",
    "HostPlacement",
    "DevicePlacement",
    "MeshPlacement",
    "make_placement",
    "resolve_placement",
    "Preprocessed",
    "preprocess",
    "ORDERINGS",
    "Level",
    "CandidateBatch",
    "generate_candidates",
    "group_reps",
    "iter_group_spans",
    "prefix_group_sizes",
    "LevelFrontier",
    "mine_levels",
    "ItemsetIndex",
    "support_test",
    "lemma_bound",
    "corollary_bound",
    "apply_bounds",
    "KyivConfig",
    "LevelStats",
    "MiningResult",
    "MiningState",
    "mine",
    "mine_preprocessed",
    "prepare",
    "brute_force_minimal_infrequent",
    "minit_minimal_infrequent",
]
