"""The Kyiv algorithm (paper Algorithm 1): breadth-first minimal τ-infrequent
itemset mining.

Per level-transition (k -> k+1):
  1. candidate joins of prefix-sharing stored itemsets     (lines 11-20)
  2. support-itemset test via stored-level lookups         (line 23, §4.4.1)
  3. at k+1 == k_max: Lemma 4.6 + Corollary 4.7 bounds     (lines 25-29)
  4. bulk row intersection (the bottleneck, Pallas kernel) (line 31)
  5. classify: absent/uniform skip (line 32), emit minimal τ-infrequent
     (lines 34-38 incl. Prop 4.1 mirror expansion), or store (line 41)

Vertex bookkeeping follows §5.2.3: type **A** = emitted minimal τ-infrequent,
type **B** = visited without performing a row intersection (support- or
bound-pruned), type **C** = the rest (intersection performed).

The driver is host-orchestrated (level control flow) with device-bulk
intersections — the same split the paper uses (Java control, hot loop on
rows), adapted so the hot loop is a TPU kernel.

**Fused classify contract** (``KyivConfig.fused_classify``, default on):
steps 4 and 5 run as *one* device pass. Each level builds a
``repro.kernels.intersect.LevelPipeline`` that holds the parent bitsets and
popcounts device-resident; every candidate batch is dispatched
asynchronously and returns ``(child, counts, classes)`` where ``classes`` is
the per-pair code CLASS_SKIP / CLASS_EMIT / CLASS_STORE computed in VMEM
(Alg. 1 lines 32-41) by the fused kernels. Host code then only gathers the
emitted rows (``classes == CLASS_EMIT``) and concatenates stored children
(``classes == CLASS_STORE``) — it never re-derives the masks from counts.
Batches are double-buffered: candidate generation, support tests and bound
pruning of batch *n+1* overlap the device intersection of batch *n*; the
only synchronisation point is ``BatchHandle.result()`` on the previous
batch. With ``fused_classify=False`` the driver falls back to host
classification (the pre-fusion path, kept as the benchmark baseline); both
paths are bit-identical on results and stats (see tests/test_fused_classify.py).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable

import numpy as np

from ..kernels.intersect import (
    CLASS_EMIT,
    CLASS_STORE,
    LegacyIntersectPipeline,
    LevelPipeline,
)
from .items import ItemTable, itemize
from .placement import resolve_placement
from .preprocess import Preprocessed, preprocess
from .prefix import CandidateBatch, Level, iter_candidate_batches
from .support import ItemsetIndex, support_test
from .bounds import apply_bounds

__all__ = [
    "KyivConfig",
    "LevelStats",
    "MiningResult",
    "MiningState",
    "mine",
    "mine_preprocessed",
    "prepare",
]


@dataclasses.dataclass
class KyivConfig:
    tau: int = 1
    kmax: int = 3
    ordering: str = "ascending"  # Def. 4.5 / §5.2.4 ablations
    use_bounds: bool = True  # Lemma 4.6 / Corollary 4.7 at k = k_max
    engine: str = "numpy"  # numpy | jnp | pallas
    # Bitset placement override: a repro.core.placement.BitsetPlacement (e.g.
    # a MeshPlacement for word-sharded SPMD mining) or an engine-name string;
    # None derives a host/device placement from `engine` via one factory
    # (placement.resolve_placement). All placements are bit-identical.
    placement: Any = None
    interpret: bool = True  # Pallas interpret mode (CPU container)
    indexed_kernel: bool = True
    expansion: str = "full"  # "full" | "paper" (single-swap, Alg. 1 lines 36-38)
    seed: int = 0  # random-ordering seed
    max_pairs_per_chunk: int = 1 << 22  # level spilling / bucket unit
    fused_classify: bool = True  # classify (Alg. 1 lines 32-41) on the engine
    locality_sort: bool = True  # locality-aware pair schedule before dispatch
    double_buffer: bool = True  # overlap host candidate gen with device batches


@dataclasses.dataclass
class LevelStats:
    k: int
    candidates: int = 0
    support_pruned: int = 0
    bound_pruned: int = 0
    intersections: int = 0
    emitted: int = 0
    skipped_absent_uniform: int = 0
    stored: int = 0
    time_total: float = 0.0
    time_intersect: float = 0.0  # dispatch + blocking device sync
    time_classify: float = 0.0  # host-side classification consumption
    level_bytes: int = 0

    @property
    def type_a(self) -> int:
        return self.emitted

    @property
    def type_b(self) -> int:
        return self.support_pruned + self.bound_pruned

    @property
    def type_c(self) -> int:
        return self.intersections - self.emitted


@dataclasses.dataclass
class MiningResult:
    """All minimal τ-infrequent itemsets up to k_max, as original item ids."""

    itemsets: list[tuple[tuple[int, ...], int]]  # (sorted item ids, |R_I|)
    stats: list[LevelStats]
    prep: Preprocessed
    config: KyivConfig
    wall_time: float

    def as_value_sets(self) -> list[tuple[tuple[tuple[int, int], ...], int]]:
        """Human-readable ((column, value), ...) form, 0-based columns."""
        t = self.prep.table
        out = []
        for ids, cnt in self.itemsets:
            out.append((tuple((int(t.col[i]), int(t.value[i])) for i in ids), cnt))
        return out

    def canonical_set(self) -> set[tuple[int, ...]]:
        return {ids for ids, _ in self.itemsets}

    @property
    def total_intersections(self) -> int:
        return sum(s.intersections for s in self.stats)

    @property
    def total_intersect_time(self) -> float:
        return sum(s.time_intersect for s in self.stats)

    @property
    def total_classify_time(self) -> float:
        return sum(s.time_classify for s in self.stats)

    @property
    def peak_level_bytes(self) -> int:
        return max((s.level_bytes for s in self.stats), default=0)


@dataclasses.dataclass
class MiningState:
    """Resumable mining state at a level boundary (Alg. 1 outer loop).

    Produced for every ``on_level_end`` callback and accepted back as
    ``resume_state`` — the typed form of what used to be an ad-hoc dict.
    Checkpoint managers and the resident mining service both hold one of
    these to restart (or warm-continue) a run without redoing earlier
    levels. Mapping-style access (``state["level"]``) is kept so existing
    checkpoint hooks keep working.
    """

    results: list[tuple[tuple[int, ...], int]]
    stats: list["LevelStats"]
    level: Level
    grandparent_index: ItemsetIndex | None
    next_k: int

    def __getitem__(self, key: str) -> Any:
        return getattr(self, key)

    def get(self, key: str, default: Any = None) -> Any:
        return getattr(self, key, default)

    def keys(self):
        return (f.name for f in dataclasses.fields(self))

    @classmethod
    def from_mapping(cls, m: "MiningState | dict[str, Any]") -> "MiningState":
        if isinstance(m, cls):
            return m
        return cls(
            results=list(m["results"]),
            stats=list(m["stats"]),
            level=m["level"],
            grandparent_index=m.get("grandparent_index"),
            next_k=m["next_k"],
        )


def _expand_mirrors(
    itemset_ids: tuple[int, ...],
    count: int,
    mirror_of: dict[int, list[int]],
    mode: str,
) -> list[tuple[tuple[int, ...], int]]:
    """Proposition 4.1 expansion of a canonical result over duplicate items.

    ``mode="paper"`` reproduces Alg. 1 lines 36-38 exactly (one swap at a
    time). ``mode="full"`` closes over all combinations of swaps — Prop. 4.1
    applies inductively, so every member of the product is minimal
    τ-infrequent; the brute-force oracle confirms the full closure is the
    complete answer (see tests).
    """
    out = [(tuple(sorted(itemset_ids)), count)]
    classes = [[i] + mirror_of.get(i, []) for i in itemset_ids]
    if mode == "paper":
        for pos, cls in enumerate(classes):
            for repl in cls[1:]:
                swapped = list(itemset_ids)
                swapped[pos] = repl
                out.append((tuple(sorted(swapped)), count))
    else:  # full product closure
        if any(len(c) > 1 for c in classes):
            for combo in itertools.product(*classes):
                out.append((tuple(sorted(combo)), count))
    # dedupe, preserve order
    seen: set[tuple[int, ...]] = set()
    uniq = []
    for ids, c in out:
        if ids not in seen:
            seen.add(ids)
            uniq.append((ids, c))
    return uniq


def _chunks(total: int, size: int):
    for s in range(0, total, size):
        yield s, min(s + size, total)


def mine_preprocessed(
    prep: Preprocessed,
    config: KyivConfig,
    *,
    intersect_fn: Callable[..., Any] | None = None,
    pipeline_factory: Callable[..., Any] | None = None,
    on_level_end: Callable[[int, "MiningState"], None] | None = None,
    resume_state: "MiningState | dict[str, Any] | None" = None,
) -> MiningResult:
    """Run Algorithm 1 on a preprocessed item table.

    ``pipeline_factory(bits, parent_counts, tau)`` builds the per-level batch
    pipeline (``repro.core.sharded.make_sharded_pipeline`` supplies a
    distributed one); ``intersect_fn(bits, pairs, write_children)`` is the
    older injection contract, adapted with host-side classification.
    ``on_level_end`` receives a :class:`MiningState` at every level boundary
    (the checkpoint hook); ``resume_state`` (a ``MiningState`` or the
    equivalent mapping from an old checkpoint) restarts there.
    """
    t_start = time.perf_counter()
    table = prep.table
    tau, kmax = config.tau, config.kmax
    n = table.n_rows
    if pipeline_factory is not None:
        make_pipeline = pipeline_factory
    elif intersect_fn is not None:
        make_pipeline = lambda bits, counts, tau_: LegacyIntersectPipeline(intersect_fn, bits)
    else:
        placement = resolve_placement(config)
        make_pipeline = lambda bits, counts, tau_: LevelPipeline(
            bits,
            counts,
            tau=tau_,
            placement=placement,
            fused_classify=config.fused_classify,
            locality_sort=config.locality_sort,
        )

    results: list[tuple[tuple[int, ...], int]] = []
    stats: list[LevelStats] = []

    # k = 1: emit τ-infrequent singletons (line 5) with mirror-free expansion
    # (every item, duplicate or not, is kept in the item table, so the
    # infrequent singletons are already complete).
    for it in prep.infrequent_items:
        results.append(((int(it),), int(table.freq[it])))
    s1 = LevelStats(k=1, emitted=len(prep.infrequent_items), stored=prep.n_l)
    s1.level_bytes = prep.l_bits.nbytes
    stats.append(s1)

    # level 1 of the prefix tree over L^< (line 8)
    level = Level(
        k=1,
        itemsets=np.arange(prep.n_l, dtype=np.int32)[:, None],
        counts=prep.l_freq.copy(),
        bits=prep.l_bits,
    )
    grandparent_index: ItemsetIndex | None = None
    level_index = ItemsetIndex(level.itemsets, level.counts, n_symbols=prep.n_l)
    k = 2

    if resume_state is not None:
        st = MiningState.from_mapping(resume_state)
        results = list(st.results)
        stats = list(st.stats)
        level = st.level
        grandparent_index = st.grandparent_index
        level_index = ItemsetIndex(level.itemsets, level.counts, n_symbols=prep.n_l)
        k = st.next_k

    while k <= kmax and level.t >= 2:
        ls = LevelStats(k=k)
        lt0 = time.perf_counter()
        write_children = k < kmax

        # level streaming (paper §6.1): candidates are generated, tested and
        # intersected in prefix-group batches bounded by a pair budget that
        # also caps the intersection working set (child bitsets + gathered
        # operands ≈ 3 * batch * W * 4 bytes). A whole level's join is never
        # materialised at once — this is what lets the miner run the paper's
        # million-row datasets in bounded host memory.
        n_words = prep.l_bits.shape[1]
        batch_cap = max(4096, (1 << 28) // max(n_words, 1))
        batch_pairs = min(config.max_pairs_per_chunk, batch_cap)

        new_itemsets, new_counts, new_bits = [], [], []
        pipe = make_pipeline(level.bits, level.counts, tau)

        def consume(entry):
            """Block on a dispatched batch and consume its classified output."""
            sel_itemsets, pairs, handle = entry
            it0 = time.perf_counter()
            child, counts, classes = handle.result()
            ls.time_intersect += time.perf_counter() - it0

            ct0 = time.perf_counter()
            if classes is None:
                # host classification (legacy intersect_fn / fused_classify=False)
                ci = level.counts[pairs[:, 0]]
                cj = level.counts[pairs[:, 1]]
                minp = np.minimum(ci, cj)
                absent_uniform = (counts == 0) | (counts == minp)
                infrequent = (~absent_uniform) & (counts <= tau)
                store = (~absent_uniform) & (~infrequent)
                inf_rows = np.nonzero(infrequent)[0]
                n_skipped = int(absent_uniform.sum())
            else:
                # fused path: the engine already classified every pair
                inf_rows = np.nonzero(classes == CLASS_EMIT)[0]
                store = classes == CLASS_STORE
                n_skipped = len(classes) - len(inf_rows) - int(store.sum())
            ls.time_classify += time.perf_counter() - ct0
            ls.skipped_absent_uniform += n_skipped

            if len(inf_rows):
                # vectorised emission: one gather for all found itemsets;
                # the per-item mirror expansion only runs for itemsets that
                # actually touch a duplicate-rowset item (rare).
                ids_mat = prep.l_items[sel_itemsets[inf_rows]]  # (r, k)
                ids_mat = np.sort(ids_mat, axis=1)  # canonical ascending ids
                cnts = counts[inf_rows]
                if prep.mirror_of:
                    mirror_items = np.fromiter(prep.mirror_of.keys(), dtype=np.int64)
                    has_mirror = np.isin(ids_mat, mirror_items).any(axis=1)
                else:
                    has_mirror = np.zeros(len(inf_rows), dtype=bool)
                plain = ~has_mirror
                results.extend(
                    zip(map(tuple, ids_mat[plain].tolist()), cnts[plain].tolist())
                )
                for r in np.nonzero(has_mirror)[0]:
                    results.extend(
                        _expand_mirrors(tuple(ids_mat[r].tolist()), int(cnts[r]),
                                        prep.mirror_of, config.expansion)
                    )
                ls.emitted += len(inf_rows)

            if write_children and store.any():
                rows = np.nonzero(store)[0]
                new_itemsets.append(sel_itemsets[rows])
                new_counts.append(counts[rows])
                new_bits.append(child[rows])

        # double-buffered batch pipeline: batch n intersects on device while
        # batch n+1 is generated, support-tested and bound-pruned on the host.
        pending = None
        for cand in iter_candidate_batches(level, batch_pairs):
            ls.candidates += cand.m

            ok = support_test(cand.itemsets, level_index)
            ls.support_pruned += int((~ok).sum())

            if k == kmax and config.use_bounds and ok.any():
                alive_idx = np.nonzero(ok)[0]
                sub = CandidateBatch(
                    i_idx=cand.i_idx[alive_idx],
                    j_idx=cand.j_idx[alive_idx],
                    itemsets=cand.itemsets[alive_idx],
                )
                pruned = apply_bounds(sub, level, level_index, grandparent_index, n, tau)
                ls.bound_pruned += int(pruned.sum())
                ok[alive_idx[pruned]] = False

            sel = np.nonzero(ok)[0]
            ls.intersections += len(sel)
            if len(sel) == 0:
                continue
            pairs = np.stack([cand.i_idx[sel], cand.j_idx[sel]], axis=1).astype(np.int32)
            it0 = time.perf_counter()
            handle = pipe.submit(pairs, write_children)  # async dispatch
            ls.time_intersect += time.perf_counter() - it0
            entry = (cand.itemsets[sel], pairs, handle)
            if not config.double_buffer:
                consume(entry)
                continue
            if pending is not None:
                consume(pending)
            pending = entry
        if pending is not None:
            consume(pending)

        if write_children and new_itemsets:
            nxt_itemsets = np.concatenate(new_itemsets, axis=0)
            nxt_counts = np.concatenate(new_counts, axis=0)
            nxt_bits = np.concatenate(new_bits, axis=0)
        else:
            nxt_itemsets = np.zeros((0, k), dtype=np.int32)
            nxt_counts = np.zeros(0, dtype=np.int64)
            nxt_bits = np.zeros((0, prep.l_bits.shape[1]), dtype=np.uint32)

        ls.stored = nxt_itemsets.shape[0]
        ls.level_bytes = nxt_bits.nbytes + (level.bits.nbytes if level.bits is not None else 0)
        ls.time_total = time.perf_counter() - lt0
        stats.append(ls)

        grandparent_index = level_index
        level = Level(k=k, itemsets=nxt_itemsets, counts=nxt_counts, bits=nxt_bits)
        level_index = ItemsetIndex(level.itemsets, level.counts, n_symbols=prep.n_l)
        k += 1

        if on_level_end is not None:
            on_level_end(
                k - 1,
                MiningState(
                    results=results,
                    stats=stats,
                    level=level,
                    grandparent_index=grandparent_index,
                    next_k=k,
                ),
            )

    return MiningResult(
        itemsets=results,
        stats=stats,
        prep=prep,
        config=config,
        wall_time=time.perf_counter() - t_start,
    )


def prepare(dataset_or_table: "np.ndarray | ItemTable", config: KyivConfig) -> Preprocessed:
    """Itemize (if needed) and §4.1-preprocess for a config — the cold half of
    :func:`mine`, split out so callers holding a prebuilt :class:`ItemTable`
    (the resident service's dataset store) can reuse it across requests."""
    table = (
        dataset_or_table
        if isinstance(dataset_or_table, ItemTable)
        else itemize(dataset_or_table)
    )
    return preprocess(table, config.tau, ordering=config.ordering, seed=config.seed)


def mine(dataset: np.ndarray, config: KyivConfig | None = None, **kw) -> MiningResult:
    """End-to-end: itemize -> preprocess (§4.1) -> Algorithm 1."""
    if config is None:
        config = KyivConfig(**kw)
    elif kw:
        config = dataclasses.replace(config, **kw)
    return mine_preprocessed(prepare(dataset, config), config)
