"""The Kyiv algorithm (paper Algorithm 1): breadth-first minimal τ-infrequent
itemset mining, driven over a device-resident level frontier.

Per level-transition (k -> k+1), all five steps of Alg. 1 lines 11-41 run
where the placement keeps the level (``repro.core.frontier``):

  1. candidate joins of prefix-sharing stored itemsets     (lines 11-20)
  2. support-itemset test via stored-level lookups         (line 23, §4.4.1)
  3. at k+1 == k_max: Lemma 4.6 + Corollary 4.7 bounds     (lines 25-29)
  4. bulk row intersection (the bottleneck, Pallas kernel) (line 31)
  5. classify + partition: absent/uniform skip (line 32), emit minimal
     τ-infrequent (lines 34-38 incl. Prop 4.1 mirror expansion), or store
     (line 41)

**What lives where.** With a device or mesh placement and the default
``KyivConfig.device_frontier`` / ``fused_classify``, a level transition is
device-to-device: candidate pair indices come from prefix-group run lengths
(``cumsum``/``searchsorted``), the support test binary-searches a packed
parent key table, the fused kernels classify in VMEM, and one stable
compaction pass splits each batch into [skip | emit | store] — stored child
bitsets never visit the host; the next level is a device-side concat. The
host keeps only the tiny frontier mirrors (itemset ids, counts, group run
lengths) and drains the emitted minimal itemsets. The only host sync points
are three scalars plus the emit/store index blocks per batch, the
``k = k_max`` bound pruning (``use_bounds``), and ``on_level_end``
checkpoint hooks (which materialise level bitsets into ``MiningState``).
With ``HostPlacement`` (``engine="numpy"``), a legacy ``intersect_fn``, or
``fused_classify=False``, the same engine runs the numpy reference path —
bit-identical on results and per-level stats by construction, and kept as
the parity oracle and benchmark baseline.

Vertex bookkeeping follows §5.2.3: type **A** = emitted minimal τ-infrequent,
type **B** = visited without performing a row intersection (support- or
bound-pruned), type **C** = the rest (intersection performed).

Batches are double-buffered: candidate generation, support tests and bound
pruning of batch *n+1* overlap the device intersection of batch *n*. Parent
levels retire eagerly once a transition completes (placement-owned device
buffers are deleted), so peak device memory tracks
``MiningResult.peak_level_bytes`` rather than every level mined so far.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import numpy as np

# Submodule imports (not the package __init__): the shared executable cache
# in ``core/exec_cache.py`` means ``kernels.intersect.ops`` re-enters
# ``repro.core`` at its bottom line; importing through the half-initialised
# kernels package namespace would cycle, the submodules are always loaded.
from ..kernels.intersect.ops import LegacyIntersectPipeline, LevelPipeline
from ..obs import metrics as _om
from ..obs.trace import span as _obs_span
from ..obs.trace import start_trace as _obs_start_trace
from .frontier import LevelFrontier, expand_mirrors, mine_levels
from .items import ItemTable, itemize
from .placement import resolve_placement
from .preprocess import Preprocessed, preprocess
from .prefix import Level
from .support import ItemsetIndex

__all__ = [
    "KyivConfig",
    "LevelStats",
    "MiningInterrupted",
    "MiningResult",
    "MiningState",
    "RunControl",
    "mine",
    "mine_preprocessed",
    "prepare",
]

_MINE_WALL = _om.histogram(
    "repro_mine_wall_seconds", "End-to-end wall time of one mining run."
)
_MINE_RUNS = _om.counter(
    "repro_mine_runs_total", "Mining runs by terminal status.", ("status",)
)
_MINE_EMITTED = _om.counter(
    "repro_mine_emitted_itemsets_total",
    "Minimal infrequent itemsets emitted across all runs.",
)
_MINE_PEAK = _om.gauge(
    "repro_mine_peak_level_bytes",
    "peak_level_bytes of the most recent mining run.",
)


class MiningInterrupted(RuntimeError):
    """A run stopped early at a batch boundary (deadline or cancellation).

    Raised by :meth:`RunControl.check` inside the level loop; callers that
    want partial-result semantics catch it (``mine_preprocessed`` does, and
    returns the itemsets emitted so far with ``MiningResult.interrupted``
    set to the reason)."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass
class RunControl:
    """Deadline + cancellation for one mining run.

    ``deadline`` is an absolute ``time.monotonic()`` instant (None = no
    deadline). The level loop calls :meth:`check` at every batch boundary —
    the run therefore stops within one batch of the deadline or of
    :meth:`cancel` being called, never mid-kernel. Everything emitted before
    the stop is a valid (but possibly incomplete) set of minimal
    τ-infrequent itemsets.
    """

    deadline: float | None = None
    _cancelled: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False
    )

    @classmethod
    def with_timeout(cls, seconds: float | None) -> "RunControl":
        return cls(
            deadline=None if seconds is None else time.monotonic() + float(seconds)
        )

    def cancel(self) -> None:
        self._cancelled.set()

    @property
    def cancelled(self) -> bool:
        return self._cancelled.is_set()

    def remaining(self) -> float | None:
        if self.deadline is None:
            return None
        return self.deadline - time.monotonic()

    def check(self) -> None:
        if self._cancelled.is_set():
            raise MiningInterrupted("cancelled")
        if self.deadline is not None and time.monotonic() > self.deadline:
            raise MiningInterrupted("deadline")

# kept where it always lived; the implementation moved to core.frontier
_expand_mirrors = expand_mirrors


@dataclasses.dataclass
class KyivConfig:
    tau: int = 1
    kmax: int = 3
    ordering: str = "ascending"  # Def. 4.5 / §5.2.4 ablations
    use_bounds: bool = True  # Lemma 4.6 / Corollary 4.7 at k = k_max
    engine: str = "numpy"  # numpy | jnp | pallas
    # Bitset placement override: a repro.core.placement.BitsetPlacement (e.g.
    # a MeshPlacement for word-sharded SPMD mining) or an engine-name string;
    # None derives a host/device placement from `engine` via one factory
    # (placement.resolve_placement). All placements are bit-identical.
    placement: Any = None
    interpret: bool = True  # Pallas interpret mode (CPU container)
    indexed_kernel: bool = True
    expansion: str = "full"  # "full" | "paper" (single-swap, Alg. 1 lines 36-38)
    seed: int = 0  # random-ordering seed
    max_pairs_per_chunk: int = 1 << 22  # level spilling / bucket unit
    fused_classify: bool = True  # classify (Alg. 1 lines 32-41) on the engine
    locality_sort: bool = True  # locality-aware pair schedule before dispatch
    double_buffer: bool = True  # overlap host candidate gen with device batches
    # run candidate generation, support tests and emit/store partitioning on
    # the placement's device (core.frontier); False pins the host reference
    # path even for device placements — the bench_frontier baseline
    device_frontier: bool = True


@dataclasses.dataclass
class LevelStats:
    k: int
    candidates: int = 0
    support_pruned: int = 0
    bound_pruned: int = 0
    intersections: int = 0
    emitted: int = 0
    skipped_absent_uniform: int = 0
    stored: int = 0
    time_total: float = 0.0
    time_intersect: float = 0.0  # dispatch + blocking device sync
    time_classify: float = 0.0  # classification / partition consumption
    time_candidates: float = 0.0  # candidate gen + support test + bounds
    level_bytes: int = 0

    @property
    def type_a(self) -> int:
        return self.emitted

    @property
    def type_b(self) -> int:
        return self.support_pruned + self.bound_pruned

    @property
    def type_c(self) -> int:
        return self.intersections - self.emitted

    @property
    def time_host_busy(self) -> float:
        """Host-side frontier work (candidate gen / support / bounds on the
        host path; batch orchestration + emit drain on the device path)."""
        return self.time_candidates + self.time_classify

    @property
    def time_device_busy(self) -> float:
        """Time attributed to device dispatch + blocking sync."""
        return self.time_intersect

    def timing_breakdown(self) -> dict:
        """JSON-friendly per-level host-idle vs device-busy split (served in
        ``/stats`` and recorded by the benchmarks)."""
        return {
            "k": self.k,
            "total": self.time_total,
            "candidates": self.time_candidates,
            "intersect": self.time_intersect,
            "classify": self.time_classify,
            "host_busy": self.time_host_busy,
            "device_busy": self.time_device_busy,
            "idle_other": max(
                0.0, self.time_total - self.time_host_busy - self.time_device_busy
            ),
        }


@dataclasses.dataclass
class MiningResult:
    """All minimal τ-infrequent itemsets up to k_max, as original item ids."""

    itemsets: list[tuple[tuple[int, ...], int]]  # (sorted item ids, |R_I|)
    stats: list[LevelStats]
    prep: Preprocessed
    config: KyivConfig
    wall_time: float
    # "deadline" | "cancelled" when the run stopped early at a batch
    # boundary — the itemsets list is then a valid partial answer and must
    # not be cached or used as an incremental base
    interrupted: str | None = None

    @property
    def completed(self) -> bool:
        return self.interrupted is None

    def as_value_sets(self) -> list[tuple[tuple[tuple[int, int], ...], int]]:
        """Human-readable ((column, value), ...) form, 0-based columns."""
        t = self.prep.table
        out = []
        for ids, cnt in self.itemsets:
            out.append((tuple((int(t.col[i]), int(t.value[i])) for i in ids), cnt))
        return out

    def canonical_set(self) -> set[tuple[int, ...]]:
        return {ids for ids, _ in self.itemsets}

    @property
    def total_intersections(self) -> int:
        return sum(s.intersections for s in self.stats)

    @property
    def total_intersect_time(self) -> float:
        return sum(s.time_intersect for s in self.stats)

    @property
    def total_classify_time(self) -> float:
        return sum(s.time_classify for s in self.stats)

    @property
    def total_candidate_time(self) -> float:
        return sum(s.time_candidates for s in self.stats)

    @property
    def peak_level_bytes(self) -> int:
        return max((s.level_bytes for s in self.stats), default=0)

    def timing_breakdown(self) -> list[dict]:
        return [s.timing_breakdown() for s in self.stats]


@dataclasses.dataclass
class MiningState:
    """Resumable mining state at a level boundary (Alg. 1 outer loop).

    Produced for every ``on_level_end`` callback and accepted back as
    ``resume_state`` — the typed form of what used to be an ad-hoc dict.
    Checkpoint managers and the resident mining service both hold one of
    these to restart (or warm-continue) a run without redoing earlier
    levels. Mapping-style access (``state["level"]``) is kept so existing
    checkpoint hooks keep working. ``level.bits`` is always materialised to
    host numpy here (the one deliberate device->host sync of the frontier
    path), so states stay picklable and resumable under any placement.
    """

    results: list[tuple[tuple[int, ...], int]]
    stats: list["LevelStats"]
    level: Level
    grandparent_index: ItemsetIndex | None
    next_k: int

    def __getitem__(self, key: str) -> Any:
        return getattr(self, key)

    def get(self, key: str, default: Any = None) -> Any:
        return getattr(self, key, default)

    def keys(self):
        return (f.name for f in dataclasses.fields(self))

    @classmethod
    def from_mapping(cls, m: "MiningState | dict[str, Any]") -> "MiningState":
        if isinstance(m, cls):
            return m
        return cls(
            results=list(m["results"]),
            stats=list(m["stats"]),
            level=m["level"],
            grandparent_index=m.get("grandparent_index"),
            next_k=m["next_k"],
        )


def mine_preprocessed(
    prep: Preprocessed,
    config: KyivConfig,
    *,
    intersect_fn: Callable[..., Any] | None = None,
    pipeline_factory: Callable[..., Any] | None = None,
    on_level_end: Callable[[int, "MiningState"], None] | None = None,
    resume_state: "MiningState | dict[str, Any] | None" = None,
    control: RunControl | None = None,
) -> MiningResult:
    """Run Algorithm 1 on a preprocessed item table.

    ``pipeline_factory(bits, parent_counts, tau)`` builds the per-level batch
    pipeline (``repro.core.sharded.make_sharded_pipeline`` supplies a
    distributed one); ``intersect_fn(bits, pairs, write_children)`` is the
    older injection contract, adapted with host-side classification.
    ``on_level_end`` receives a :class:`MiningState` at every level boundary
    (the checkpoint hook); ``resume_state`` (a ``MiningState`` or the
    equivalent mapping from an old checkpoint) restarts there. ``control``
    carries a per-request deadline/cancellation checked at every batch
    boundary — an interrupted run returns the partial result with
    ``MiningResult.interrupted`` set instead of raising. The level loop
    itself lives in :func:`repro.core.frontier.mine_levels`.

    Every run records into :mod:`repro.obs`: a ``mine`` span (the trace
    root when no request trace is active, a child span otherwise) over
    ``mine.seed`` + per-level ``mine.level`` children, plus the
    ``repro_mine_*`` metric families.
    """
    with _obs_start_trace("mine") as _msp:
        try:
            result = _mine_preprocessed_inner(
                prep,
                config,
                intersect_fn=intersect_fn,
                pipeline_factory=pipeline_factory,
                on_level_end=on_level_end,
                resume_state=resume_state,
                control=control,
            )
        except Exception:
            _MINE_RUNS.inc(status="error")
            _msp.set(status="error")
            raise
        status = "interrupted" if result.interrupted else "ok"
        _msp.set(
            status=status,
            emitted=len(result.itemsets),
            levels=len(result.stats),
            peak_level_bytes=result.peak_level_bytes,
        )
        _MINE_WALL.observe(result.wall_time)
        _MINE_RUNS.inc(status=status)
        _MINE_EMITTED.inc(len(result.itemsets))
        _MINE_PEAK.set(result.peak_level_bytes)
    return result


def _mine_preprocessed_inner(
    prep: Preprocessed,
    config: KyivConfig,
    *,
    intersect_fn: Callable[..., Any] | None = None,
    pipeline_factory: Callable[..., Any] | None = None,
    on_level_end: Callable[[int, "MiningState"], None] | None = None,
    resume_state: "MiningState | dict[str, Any] | None" = None,
    control: RunControl | None = None,
) -> MiningResult:
    t_start = time.perf_counter()
    table = prep.table
    if pipeline_factory is not None:
        make_pipeline = pipeline_factory
    elif intersect_fn is not None:
        make_pipeline = lambda bits, counts, tau_: LegacyIntersectPipeline(intersect_fn, bits)
    else:
        placement = resolve_placement(config)
        make_pipeline = lambda bits, counts, tau_: LevelPipeline(
            bits,
            counts,
            tau=tau_,
            placement=placement,
            fused_classify=config.fused_classify,
            locality_sort=config.locality_sort,
        )

    results: list[tuple[tuple[int, ...], int]] = []
    stats: list[LevelStats] = []

    with _obs_span("mine.seed"):
        # k = 1: emit τ-infrequent singletons (line 5) with mirror-free
        # expansion (every item, duplicate or not, is kept in the item
        # table, so the infrequent singletons are already complete).
        for it in prep.infrequent_items:
            results.append(((int(it),), int(table.freq[it])))
        s1 = LevelStats(k=1, emitted=len(prep.infrequent_items), stored=prep.n_l)
        s1.level_bytes = prep.l_bits.nbytes
        stats.append(s1)

        # level 1 of the prefix tree over L^< (line 8)
        frontier = LevelFrontier(
            k=1,
            itemsets=np.arange(prep.n_l, dtype=np.int32)[:, None],
            counts=prep.l_freq.copy(),
            bits=prep.l_bits,
        )
        grandparent_index: ItemsetIndex | None = None
        start_k = 2

        if resume_state is not None:
            st = MiningState.from_mapping(resume_state)
            results = list(st.results)
            stats = list(st.stats)
            frontier = LevelFrontier.from_level(st.level)
            grandparent_index = st.grandparent_index
            start_k = st.next_k

    def make_state(next_k: int, fr: LevelFrontier, gp) -> MiningState:
        return MiningState(
            results=results,
            stats=stats,
            level=fr.as_level(host_bits=True),
            grandparent_index=gp,
            next_k=next_k,
        )

    interrupted: str | None = None
    try:
        mine_levels(
            prep,
            config,
            make_pipeline,
            results,
            stats,
            frontier=frontier,
            grandparent_index=grandparent_index,
            start_k=start_k,
            on_level_end=on_level_end,
            make_state=make_state,
            control=control,
        )
    except MiningInterrupted as e:
        interrupted = e.reason

    return MiningResult(
        itemsets=results,
        stats=stats,
        prep=prep,
        config=config,
        wall_time=time.perf_counter() - t_start,
        interrupted=interrupted,
    )


def prepare(dataset_or_table: "np.ndarray | ItemTable", config: KyivConfig) -> Preprocessed:
    """Itemize (if needed) and §4.1-preprocess for a config — the cold half of
    :func:`mine`, split out so callers holding a prebuilt :class:`ItemTable`
    (the resident service's dataset store) can reuse it across requests."""
    table = (
        dataset_or_table
        if isinstance(dataset_or_table, ItemTable)
        else itemize(dataset_or_table)
    )
    return preprocess(table, config.tau, ordering=config.ordering, seed=config.seed)


def mine(dataset: np.ndarray, config: KyivConfig | None = None, **kw) -> MiningResult:
    """End-to-end: itemize -> preprocess (§4.1) -> Algorithm 1."""
    if config is None:
        config = KyivConfig(**kw)
    elif kw:
        config = dataclasses.replace(config, **kw)
    return mine_preprocessed(prepare(dataset, config), config)
