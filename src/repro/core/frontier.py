"""The device-resident BFS level frontier (Alg. 1 lines 11-41 per level).

Before this module the driver ran every level *transition* on the host:
``core/prefix.py`` enumerated prefix-join pairs in numpy, ``core/support.py``
ran the support-itemset test against a host index, and the driver gathered
every batch's outputs back to classify, emit and rebuild the next level — so
at wide levels the device sat idle behind host candidate churn, with bitsets
ping-ponging host<->device once per level.

:class:`LevelFrontier` makes the frontier a first-class structure — the
itemset id table, counts and prefix-group run lengths as host mirrors (tiny:
``(t, k)`` ints) plus the level *bitsets* wherever the placement keeps them
(host numpy, one device, or a word-sharded mesh) — and
:func:`mine_levels` is the one level-transition engine both paths share:

* **Host reference** (``HostPlacement``, legacy ``intersect_fn`` injection,
  or ``fused_classify=False``): exactly the numpy path the driver always
  ran, routed through ``placement.prepare_frontier`` /
  ``placement.frontier_dispatch`` — kept bit-identical by construction and
  used as the parity oracle.
* **Device frontier** (``DevicePlacement`` / ``MeshPlacement`` with
  ``fused_classify=True``): candidate pair indices are generated from the
  prefix-group run lengths with ``cumsum``/``searchsorted`` on device, the
  support test binary-searches a packed parent key table on device, the
  fused intersect+classify kernels consume the *device* pair indices
  directly (``LevelPipeline.submit_padded``), and one stable compaction
  pass partitions each classified batch into [skip | emit | store]
  segments. The host drains only the emitted minimal itemsets (a few ints
  per emit) and the stored ``(i, j, count)`` triples for the next level's id
  mirror; stored child *bitsets* never leave the device — the next level is
  a device-side concatenation. Host sync points per batch: the survivor
  count and the two partition counts (three scalars), plus the
  emit/store index blocks.

Remaining host sync points: Lemma 4.6 / Corollary 4.7 bound pruning at
``k = k_max`` (``use_bounds=True``) pulls that final count-only level's
surviving candidates to the host, and an ``on_level_end`` checkpoint hook
materialises the level bitsets into the :class:`~repro.core.kyiv.MiningState`.

Both paths batch over the same prefix-group spans
(``prefix.iter_group_spans``) and emit in the same candidate order, so
results *and* per-level stats are bit-identical (property-tested in
``tests/test_frontier.py`` / ``tests/test_frontier_prop.py``).

Levels retire eagerly: once a transition completes, the parent pipeline's
placement-owned buffers, the frontier id/key tables, and driver-owned
device bitsets are dropped (``LevelPipeline.retire`` /
``BitsetPlacement.release``), so peak device memory tracks the two live
levels of a transition — ``MiningResult.peak_level_bytes`` — instead of
every parent level mined so far.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any

import numpy as np

from ..kernels.intersect.ref import CLASS_EMIT, CLASS_STORE
from ..obs import cost as _obs_cost
from ..obs import metrics as _om
from ..obs.trace import device_sync as _obs_device_sync
from ..obs.trace import span as _obs_span
from .bounds import apply_bounds
from .placement import HostPlacement
from .prefix import (
    CandidateBatch,
    Level,
    group_reps,
    iter_group_spans,
    prefix_group_sizes,
)
from .support import ItemsetIndex

__all__ = ["LevelFrontier", "expand_mirrors", "mine_levels"]

_HOST_REFERENCE = HostPlacement()

# Per-stage level timings land in the fixed log-scale time ladder — the
# paper's Fig. 2 time-distribution view, as a live histogram per stage.
_LEVEL_SECONDS = _om.histogram(
    "repro_mine_level_seconds",
    "Per-level wall time by mining stage (candidates=gen+support+bounds, "
    "intersect=dispatch+sync, classify=partition/consume, total).",
    ("stage",),
)
_LEVEL_PAIRS = _om.counter(
    "repro_mine_pairs_total",
    "Candidate-pair outcomes across all mined levels.",
    ("outcome",),
)
_LEVELS_TOTAL = _om.counter(
    "repro_mine_levels_total",
    "Level transitions mined, by frontier path.",
    ("path",),
)


def _record_level(ls, path: str, sp, n_rows: int = 0) -> None:
    """Fold one finished level's stats into the registry + its span, and
    into the request's CostEnvelope (no-op without one attached)."""
    env = _obs_cost.current()
    if env is not None:
        env.add(
            levels=1,
            candidate_pairs=ls.candidates,
            rows_scanned=ls.intersections * n_rows,
            device_bytes=ls.level_bytes if path == "device" else 0,
            itemsets_emitted=ls.emitted,
        )
        if path == "device":
            env.add_device_time(ls.time_intersect)
    _LEVEL_SECONDS.observe(ls.time_candidates, stage="candidates")
    _LEVEL_SECONDS.observe(ls.time_intersect, stage="intersect")
    _LEVEL_SECONDS.observe(ls.time_classify, stage="classify")
    _LEVEL_SECONDS.observe(ls.time_total, stage="total")
    _LEVEL_PAIRS.inc(ls.candidates, outcome="candidates")
    _LEVEL_PAIRS.inc(ls.support_pruned, outcome="support_pruned")
    _LEVEL_PAIRS.inc(ls.bound_pruned, outcome="bound_pruned")
    _LEVEL_PAIRS.inc(ls.intersections, outcome="intersections")
    _LEVEL_PAIRS.inc(ls.skipped_absent_uniform, outcome="skipped")
    _LEVEL_PAIRS.inc(ls.emitted, outcome="emitted")
    _LEVEL_PAIRS.inc(ls.stored, outcome="stored")
    _LEVELS_TOTAL.inc(path=path)
    sp.set(
        path=path,
        candidates=ls.candidates,
        emitted=ls.emitted,
        stored=ls.stored,
        level_bytes=ls.level_bytes,
    )


def expand_mirrors(
    itemset_ids: tuple[int, ...],
    count: int,
    mirror_of: dict[int, list[int]],
    mode: str,
) -> list[tuple[tuple[int, ...], int]]:
    """Proposition 4.1 expansion of a canonical result over duplicate items.

    ``mode="paper"`` reproduces Alg. 1 lines 36-38 exactly (one swap at a
    time). ``mode="full"`` closes over all combinations of swaps — Prop. 4.1
    applies inductively, so every member of the product is minimal
    τ-infrequent; the brute-force oracle confirms the full closure is the
    complete answer (see tests).
    """
    out = [(tuple(sorted(itemset_ids)), count)]
    classes = [[i] + mirror_of.get(i, []) for i in itemset_ids]
    if mode == "paper":
        for pos, cls in enumerate(classes):
            for repl in cls[1:]:
                swapped = list(itemset_ids)
                swapped[pos] = repl
                out.append((tuple(sorted(swapped)), count))
    else:  # full product closure
        if any(len(c) > 1 for c in classes):
            for combo in itertools.product(*classes):
                out.append((tuple(sorted(combo)), count))
    # dedupe, preserve order
    seen: set[tuple[int, ...]] = set()
    uniq = []
    for ids, c in out:
        if ids not in seen:
            seen.add(ids)
            uniq.append((ids, c))
    return uniq


@dataclasses.dataclass
class LevelFrontier:
    """One stored BFS level, frontier form.

    ``itemsets``/``counts`` are host mirrors (cheap — ``(t, k)`` int32 /
    ``(t,)`` int64; emission, resume checkpoints and the k_max bound pruning
    read them), ``bits`` lives wherever the placement keeps level bitsets
    (host numpy for the reference path, a device or mesh array chained
    level-to-level for the device frontier). ``owns_bits`` marks device
    arrays the driver itself created (a level's store-partition concat) and
    may therefore delete on retirement — seed bitsets (level 1, resident
    store gathers, resume states) are never the driver's to drop.
    """

    k: int
    itemsets: np.ndarray
    counts: np.ndarray
    bits: Any
    owns_bits: bool = False

    @property
    def t(self) -> int:
        return int(self.itemsets.shape[0])

    def as_level(self, *, host_bits: bool = False) -> Level:
        bits = self.bits
        if host_bits and bits is not None and not isinstance(bits, np.ndarray):
            bits = np.asarray(bits)
        return Level(k=self.k, itemsets=self.itemsets, counts=self.counts, bits=bits)

    @classmethod
    def from_level(cls, level: Level) -> "LevelFrontier":
        return cls(
            k=level.k,
            itemsets=np.asarray(level.itemsets),
            counts=np.asarray(level.counts),
            bits=level.bits,
            owns_bits=False,
        )

    def retire(self) -> None:
        """Drop the level's bitsets; device arrays the driver owns are
        deleted eagerly (PJRT defers the actual free past in-flight uses)."""
        bits, self.bits = self.bits, None
        if self.owns_bits and bits is not None and not isinstance(bits, np.ndarray):
            if hasattr(bits, "is_deleted") and not bits.is_deleted():
                bits.delete()


def _device_frontier_capable(placement, pipe, config) -> bool:
    """Device frontier preconditions: a non-host placement that implements
    the frontier ops, fused classification (the partition pass consumes
    class codes), and a pipeline that accepts device pair batches."""
    return (
        placement is not None
        and getattr(placement, "kind", "host") != "host"
        and getattr(config, "device_frontier", True)
        # placements may veto per backend (MeshPlacement defaults off on the
        # emulated CPU mesh, where per-batch collectives stall in rendezvous)
        and getattr(placement, "use_device_frontier", True)
        # the pipeline's own flag, not the config's: a pipeline_factory may
        # pin host classification (the fused_classify=False baseline)
        and getattr(pipe, "fused_classify", False)
        and hasattr(placement, "frontier_dispatch")
        and hasattr(pipe, "submit_padded")
    )


def _emit_rows(results, ls, prep, expansion, lpos_mat, cnts) -> None:
    """Drain one batch's emitted minimal itemsets (vectorised; the per-item
    mirror expansion only runs for itemsets that touch a duplicate-rowset
    item, which is rare)."""
    ids_mat = prep.l_items[lpos_mat]  # L-positions -> original item ids
    ids_mat = np.sort(ids_mat, axis=1)  # canonical ascending ids
    if prep.mirror_of:
        mirror_items = np.fromiter(prep.mirror_of.keys(), dtype=np.int64)
        has_mirror = np.isin(ids_mat, mirror_items).any(axis=1)
    else:
        has_mirror = np.zeros(ids_mat.shape[0], dtype=bool)
    plain = ~has_mirror
    results.extend(zip(map(tuple, ids_mat[plain].tolist()), cnts[plain].tolist()))
    for r in np.nonzero(has_mirror)[0]:
        results.extend(
            expand_mirrors(
                tuple(ids_mat[r].tolist()), int(cnts[r]), prep.mirror_of, expansion
            )
        )
    ls.emitted += ids_mat.shape[0]


def _candidate_lpos(frontier: LevelFrontier, pairs: np.ndarray) -> np.ndarray:
    """Candidate L-position itemsets of (i, j) parent pairs: the I parent's
    row plus the J parent's last item (shared-prefix join)."""
    return np.concatenate(
        [frontier.itemsets[pairs[:, 0]], frontier.itemsets[pairs[:, 1], -1:]], axis=1
    ).astype(np.int32)


def mine_levels(
    prep,
    config,
    make_pipeline,
    results: list,
    stats: list,
    *,
    frontier: LevelFrontier,
    grandparent_index: ItemsetIndex | None,
    start_k: int,
    on_level_end=None,
    make_state=None,
    control=None,
) -> None:
    """Run Alg. 1's outer loop from level ``start_k - 1``'s stored frontier.

    Appends emitted itemsets to ``results`` and a ``LevelStats`` per level to
    ``stats`` (both in the exact order of the pre-frontier driver);
    ``make_state(k, frontier, grandparent_index)`` builds the
    ``MiningState`` handed to ``on_level_end``. ``control`` (a
    ``repro.core.kyiv.RunControl``) is checked at every batch boundary and at
    level boundaries — a tripped deadline or cancellation raises
    ``MiningInterrupted`` with everything emitted so far already in
    ``results`` (partial-result semantics; the caller decides what to do
    with them).
    """
    tau, kmax = config.tau, config.kmax
    n = prep.table.n_rows
    k = start_k

    n_words = prep.l_bits.shape[1]
    batch_cap = max(4096, (1 << 28) // max(n_words, 1))
    batch_pairs = min(config.max_pairs_per_chunk, batch_cap)

    while k <= kmax and frontier.t >= 2:
        from .kyiv import LevelStats  # deferred: kyiv imports this module

        if control is not None:
            control.check()
        with _obs_span("mine.level", k=k) as _lsp:
            ls = LevelStats(k=k)
            lt0 = time.perf_counter()
            write_children = k < kmax

            pipe = make_pipeline(frontier.bits, frontier.counts, tau)
            placement = getattr(pipe, "placement", None)
            device_path = _device_frontier_capable(placement, pipe, config)

            # the host index of this parent level is needed beyond the host
            # path when checkpoints will serialise it, or when this / the next
            # transition runs the k_max bound pruning (its grandparent lookups)
            need_index = on_level_end is not None or (
                config.use_bounds and kmax - 1 <= k <= kmax
            )

            if device_path:
                nxt, level_index = _advance_device(
                    frontier,
                    pipe,
                    placement,
                    prep,
                    config,
                    ls,
                    results,
                    k,
                    write_children,
                    batch_pairs,
                    grandparent_index,
                    n,
                    need_index,
                    control,
                )
            else:
                nxt, level_index = _advance_host(
                    frontier,
                    pipe,
                    placement,
                    prep,
                    config,
                    ls,
                    results,
                    k,
                    write_children,
                    batch_pairs,
                    grandparent_index,
                    n,
                    control,
                )

            ls.time_total = time.perf_counter() - lt0
            stats.append(ls)
            _record_level(ls, "device" if device_path else "host", _lsp, n)

            # eager retirement: the parent level's pipeline residency,
            # frontier tables and driver-owned bitsets all drop now — device
            # memory holds only the transition's two live levels
            # (peak_level_bytes)
            if hasattr(pipe, "retire"):
                pipe.retire()
            grandparent_index = level_index
            old = frontier
            frontier = nxt
            k += 1

            if on_level_end is not None:
                with _obs_span("mine.checkpoint", k=k - 1):
                    on_level_end(k - 1, make_state(k, frontier, grandparent_index))
            old.retire()

    frontier.retire()


def _advance_host(
    frontier,
    pipe,
    placement,
    prep,
    config,
    ls,
    results,
    k,
    write_children,
    batch_pairs,
    grandparent_index,
    n,
    control=None,
):
    """One level transition on the host reference path (also serves legacy
    ``intersect_fn`` pipelines and ``fused_classify=False``) — today's numpy
    flow, batch-for-batch and bit-for-bit."""
    tau = config.tau
    host_frontier = (
        placement
        if placement is not None and getattr(placement, "kind", None) == "host"
        else _HOST_REFERENCE
    )
    with _obs_span("frontier.candidates", phase="prepare"):
        ct0 = time.perf_counter()
        fstate = host_frontier.prepare_frontier(
            frontier.itemsets, frontier.counts, prep.n_l
        )
        level_index = fstate  # the host frontier state *is* the support index
        sizes = prefix_group_sizes(frontier.itemsets)
        ls.time_candidates += time.perf_counter() - ct0

    level = frontier.as_level()
    new_itemsets, new_counts, new_bits = [], [], []

    def consume(entry):
        """Block on a dispatched batch and consume its classified output."""
        sel_itemsets, pairs, handle = entry
        it0 = time.perf_counter()
        with _obs_span("intersect.sync"):
            child, counts, classes = handle.result()
        ls.time_intersect += time.perf_counter() - it0

        with _obs_span("level.classify"):
            ct0 = time.perf_counter()
            if classes is None:
                # host classification (legacy intersect_fn / fused_classify=False)
                ci = level.counts[pairs[:, 0]]
                cj = level.counts[pairs[:, 1]]
                minp = np.minimum(ci, cj)
                absent_uniform = (counts == 0) | (counts == minp)
                infrequent = (~absent_uniform) & (counts <= tau)
                store = (~absent_uniform) & (~infrequent)
                inf_rows = np.nonzero(infrequent)[0]
                n_skipped = int(absent_uniform.sum())
            else:
                # fused path: the engine already classified every pair
                inf_rows = np.nonzero(classes == CLASS_EMIT)[0]
                store = classes == CLASS_STORE
                n_skipped = len(classes) - len(inf_rows) - int(store.sum())
            # the classify clock stops here, before emission/store
            # bookkeeping — exactly where the pre-frontier driver stopped it,
            # so bench_fused_pipeline's classify-speedup history stays
            # comparable
            ls.time_classify += time.perf_counter() - ct0
        ls.skipped_absent_uniform += n_skipped

        if len(inf_rows):
            _emit_rows(
                results, ls, prep, config.expansion,
                sel_itemsets[inf_rows], counts[inf_rows],
            )

        if write_children and store.any():
            rows = np.nonzero(store)[0]
            new_itemsets.append(sel_itemsets[rows])
            new_counts.append(counts[rows])
            new_bits.append(child[rows])

    # double-buffered batch pipeline: batch n intersects on device while
    # batch n+1 is generated, support-tested and bound-pruned on the host.
    pending = None
    for lo, hi, n_pairs in iter_group_spans(sizes, batch_pairs):
        if n_pairs == 0:
            continue
        if control is not None:
            control.check()
        with _obs_span("frontier.candidates"):
            ct0 = time.perf_counter()
            cand, ok = host_frontier.frontier_dispatch(fstate, lo, hi, n_pairs)
            ls.candidates += cand.m
            ls.support_pruned += int((~ok).sum())
            ls.time_candidates += time.perf_counter() - ct0

            if k == config.kmax and config.use_bounds and ok.any():
                ct0 = time.perf_counter()
                alive_idx = np.nonzero(ok)[0]
                sub = CandidateBatch(
                    i_idx=cand.i_idx[alive_idx],
                    j_idx=cand.j_idx[alive_idx],
                    itemsets=cand.itemsets[alive_idx],
                )
                pruned = apply_bounds(
                    sub, level, level_index, grandparent_index, n, tau
                )
                ls.bound_pruned += int(pruned.sum())
                ok[alive_idx[pruned]] = False
                ls.time_candidates += time.perf_counter() - ct0

        sel = np.nonzero(ok)[0]
        ls.intersections += len(sel)
        if len(sel) == 0:
            continue
        pairs = np.stack([cand.i_idx[sel], cand.j_idx[sel]], axis=1).astype(np.int32)
        it0 = time.perf_counter()
        with _obs_span("intersect.dispatch", pairs=len(sel)):
            handle = pipe.submit(pairs, write_children)  # async dispatch
        ls.time_intersect += time.perf_counter() - it0
        entry = (cand.itemsets[sel], pairs, handle)
        if not config.double_buffer:
            consume(entry)
            continue
        if pending is not None:
            consume(pending)
        pending = entry
    if pending is not None:
        consume(pending)

    if write_children and new_itemsets:
        nxt_itemsets = np.concatenate(new_itemsets, axis=0)
        nxt_counts = np.concatenate(new_counts, axis=0)
        nxt_bits = np.concatenate(new_bits, axis=0)
    else:
        nxt_itemsets = np.zeros((0, k), dtype=np.int32)
        nxt_counts = np.zeros(0, dtype=np.int64)
        nxt_bits = np.zeros((0, prep.l_bits.shape[1]), dtype=np.uint32)

    ls.stored = nxt_itemsets.shape[0]
    ls.level_bytes = nxt_bits.nbytes + (
        level.bits.nbytes if isinstance(level.bits, np.ndarray) else 0
    )
    return (
        LevelFrontier(k=k, itemsets=nxt_itemsets, counts=nxt_counts, bits=nxt_bits),
        level_index,
    )


def _advance_device(
    frontier,
    pipe,
    placement,
    prep,
    config,
    ls,
    results,
    k,
    write_children,
    batch_pairs,
    grandparent_index,
    n,
    need_index,
    control=None,
):
    """One level transition on the device frontier.

    Per batch: candidate gen + support test + survivor compaction + fused
    intersect/classify + emit/store partition, all device-to-device; the
    host syncs on three scalars and the emit/store index blocks. Only the
    ``k = k_max`` bound pruning (``use_bounds``) pulls survivors to the host
    — that level is count-only, so no bitsets move either way.
    """
    tau = config.tau
    with _obs_span("frontier.candidates", phase="prepare"):
        ct0 = time.perf_counter()
        fstate = placement.prepare_frontier(
            frontier.itemsets, frontier.counts, prep.n_l
        )
        sizes = prefix_group_sizes(frontier.itemsets)
        ls.time_candidates += time.perf_counter() - ct0

    host_bounds = k == config.kmax and config.use_bounds
    level_index = None
    if host_bounds or need_index:
        level_index = ItemsetIndex(frontier.itemsets, frontier.counts, n_symbols=prep.n_l)

    new_pairs, new_counts, new_children = [], [], []

    def consume(entry):
        if entry[0] == "host":
            _, lpos, pairs, handle = entry
            it0 = time.perf_counter()
            with _obs_span("intersect.sync"):
                child, counts, classes = handle.result()
            ls.time_intersect += time.perf_counter() - it0
            with _obs_span("level.classify"):
                ct0 = time.perf_counter()
                inf_rows = np.nonzero(classes == CLASS_EMIT)[0]
                store = classes == CLASS_STORE
                ls.time_classify += time.perf_counter() - ct0
            ls.skipped_absent_uniform += len(classes) - len(inf_rows) - int(store.sum())
            if len(inf_rows):
                _emit_rows(
                    results, ls, prep, config.expansion,
                    lpos[inf_rows], counts[inf_rows],
                )
            return

        _, mb, cpairs, n_ok_dev, handle = entry
        it0 = time.perf_counter()
        with _obs_span("intersect.sync"):
            child_d, cnt_d, cls_d = handle.raw()
            n_ok = int(n_ok_dev)  # first host sync of the batch
        ls.time_intersect += time.perf_counter() - it0
        ls.support_pruned += mb - n_ok
        ls.intersections += n_ok
        if n_ok == 0:
            return

        with _obs_span("level.classify"):
            ct0 = time.perf_counter()
            order, n_emit_d, n_store_d = placement.frontier_partition(cls_d)
            # the batch's bookkeeping arrays (segment order, pairs, counts)
            # are a few ints per pair — fetch them whole and slice on the
            # host, so the only per-batch device programs are the three
            # jitted bucket-static ops (dispatch / mask / partition); a
            # dynamically shaped device op per batch would recompile
            # endlessly (SPMD programs on a mesh make that pathological)
            order_h = np.asarray(order)
            pairs_h = np.asarray(cpairs)
            cnt_h = np.asarray(cnt_d).astype(np.int64)
            n_emit, n_store = int(n_emit_d), int(n_store_d)
            bucket = int(pairs_h.shape[0])
            seg = bucket - n_emit - n_store  # skip segment incl. padding
            # classify clock covers partition + fetches, not emission/store
            # bookkeeping — mirroring the host path's historical attribution
            ls.time_classify += time.perf_counter() - ct0
        ls.skipped_absent_uniform += n_ok - n_emit - n_store

        if n_emit:
            emit_rows = order_h[seg : seg + n_emit]
            _emit_rows(
                results, ls, prep, config.expansion,
                _candidate_lpos(frontier, pairs_h[emit_rows]), cnt_h[emit_rows],
            )
        if write_children and n_store:
            store_rows = order_h[seg + n_emit : seg + n_emit + n_store]
            new_pairs.append(pairs_h[store_rows])
            new_counts.append(cnt_h[store_rows])
            # child bitsets stay on device: gather the store segment through
            # a power-of-two padded index (repeating row 0) so the gather
            # executable is shared across batches and levels
            import jax.numpy as jnp

            from ..kernels.intersect.ops import next_bucket

            sb = next_bucket(n_store, 16)
            idx = np.zeros(sb, dtype=np.int32)
            idx[:n_store] = store_rows
            new_children.append((child_d[jnp.asarray(idx)], n_store))

    pending = None
    for lo, hi, n_pairs in iter_group_spans(sizes, batch_pairs):
        if n_pairs == 0:
            continue
        if control is not None:
            control.check()
        ls.candidates += n_pairs
        with _obs_span("frontier.candidates"):
            ct0 = time.perf_counter()
            pairs_d, ok_d = placement.frontier_dispatch(fstate, lo, hi, n_pairs)
            ls.time_candidates += time.perf_counter() - ct0
            _obs_device_sync(pairs_d, ok_d)

        if host_bounds:
            # the one remaining host-assisted step: Lemma 4.6/Cor. 4.7 needs
            # the grandparent lookups, so survivors come to the host here
            with _obs_span("frontier.candidates", phase="bounds"):
                ct0 = time.perf_counter()
                okh = np.asarray(ok_d)
                pairs_h = np.asarray(pairs_d)[okh]
                n_sup = int(okh.sum())
                ls.support_pruned += n_pairs - n_sup
                if n_sup == 0:
                    ls.time_candidates += time.perf_counter() - ct0
                    continue
                lpos = _candidate_lpos(frontier, pairs_h)
                sub = CandidateBatch(
                    i_idx=pairs_h[:, 0].astype(np.int64),
                    j_idx=pairs_h[:, 1].astype(np.int64),
                    itemsets=lpos,
                )
                pruned = apply_bounds(
                    sub, frontier.as_level(), level_index, grandparent_index,
                    n, tau,
                )
                ls.bound_pruned += int(pruned.sum())
                keep = ~pruned
                ls.intersections += int(keep.sum())
                ls.time_candidates += time.perf_counter() - ct0
            if not keep.any():
                continue
            sel_pairs = np.ascontiguousarray(pairs_h[keep])
            it0 = time.perf_counter()
            with _obs_span("intersect.dispatch", pairs=int(keep.sum())):
                handle = pipe.submit(sel_pairs, write_children)
            ls.time_intersect += time.perf_counter() - it0
            entry = ("host", lpos[keep], sel_pairs, handle)
        else:
            with _obs_span("frontier.candidates", phase="mask"):
                ct0 = time.perf_counter()
                cpairs, n_ok_dev = placement.frontier_mask(fstate, pairs_d, ok_d)
                ls.time_candidates += time.perf_counter() - ct0
            it0 = time.perf_counter()
            with _obs_span("intersect.dispatch", pairs=n_pairs):
                handle = pipe.submit_padded(cpairs, n_pairs, write_children)
            ls.time_intersect += time.perf_counter() - it0
            entry = ("dev", n_pairs, cpairs, n_ok_dev, handle)

        if not config.double_buffer:
            consume(entry)
            continue
        if pending is not None:
            consume(pending)
        pending = entry
    if pending is not None:
        consume(pending)

    # logical dataset word count, not frontier.bits.shape[1]: mesh kernels
    # word-pad their children, and the level_bytes accounting must match the
    # host reference exactly
    w_words = int(prep.l_bits.shape[1])
    if write_children and new_pairs:
        sp = np.concatenate(new_pairs, axis=0)
        nxt_itemsets = _candidate_lpos(frontier, sp)
        nxt_counts = np.concatenate(new_counts, axis=0)
        # assemble the next level's bitsets device-to-device: one concat of
        # the bucket-padded store segments + one gather of the real rows —
        # exactly two dynamically-shaped device programs per level
        import jax.numpy as jnp

        rows = []
        off = 0
        for seg_child, n_store in new_children:
            rows.append(off + np.arange(n_store, dtype=np.int64))
            off += int(seg_child.shape[0])
        big = (
            new_children[0][0]
            if len(new_children) == 1
            else jnp.concatenate([c for c, _ in new_children], axis=0)
        )
        nxt_bits = big[jnp.asarray(np.concatenate(rows))]
        owns = True
    else:
        nxt_itemsets = np.zeros((0, k), dtype=np.int32)
        nxt_counts = np.zeros(0, dtype=np.int64)
        nxt_bits = np.zeros((0, prep.l_bits.shape[1]), dtype=np.uint32)
        owns = False

    ls.stored = nxt_itemsets.shape[0]
    # logical sizes (t * W * 4 bytes): identical accounting to the host path
    # even when a mesh pads the word axis
    ls.level_bytes = nxt_itemsets.shape[0] * w_words * 4 + frontier.t * w_words * 4
    release = getattr(placement, "release", None)
    if release is not None:
        release(fstate)
    return (
        LevelFrontier(
            k=k, itemsets=nxt_itemsets, counts=nxt_counts, bits=nxt_bits, owns_bits=owns
        ),
        level_index,
    )
