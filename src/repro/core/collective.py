"""Host-level fleet collectives over the JAX coordination service.

The multi-host miner needs exactly one cross-process primitive inside the
level loop — summing per-process partial popcounts — plus a handful of
control-plane exchanges (watermark agreement, candidate-pool unions, result
digests). On TPU/GPU pods those could ride the DCN all-reduce, but the CPU
backend does not implement cross-process XLA computations at all
(``Multiprocess computations aren't implemented on the CPU backend``), and
the control-plane exchanges are host-side anyway. So the fleet speaks a
single transport that works on every backend `jax.distributed.initialize`
supports: the coordination-service **key-value store** that already carries
JAX's own bootstrap traffic.

Protocol
--------

Every collective is one *round*. At round ``n`` each process

1. deletes its own round ``n-2`` key (safe: completing round ``n-1`` is a
   rendezvous, so every peer has already read the ``n-2`` keys — see the
   inline proof on :meth:`FleetCollective._gc`),
2. publishes its payload under ``<ns>/<n>/<pid>``,
3. blocking-reads the other ``P-1`` keys.

Rounds are strictly ordered per process and every process must execute the
*same sequence* of collectives — the fleet placement and coordinator are
built so that all collective call sites are driven by globally-identical
state (global counts, fanned-out commands). A peer that dies mid-round
surfaces as :class:`FleetTimeout` on the survivors, which the coordinator
maps to its single-host degradation path.

:class:`LoopbackCollective` is the ``P == 1`` implementation (no
coordination service, zero overhead): it lets every fleet code path run —
and be property-tested — in a single ordinary process.
"""

from __future__ import annotations

import pickle
import threading
import time

import numpy as np

__all__ = [
    "Collective",
    "FleetCollective",
    "FleetDesyncError",
    "FleetTimeout",
    "LoopbackCollective",
]


class FleetTimeout(RuntimeError):
    """A peer failed to publish its round payload within the deadline —
    the fleet-level analogue of a device loss; the coordinator degrades."""


class FleetDesyncError(RuntimeError):
    """Processes disagreed on a value that must be replicated (version
    watermarks, result digests). Always a bug or corruption, never retried."""


class Collective:
    """Interface shared by the loopback and multi-process implementations.

    ``pid`` / ``nproc`` identify this process; :meth:`allgather` is the one
    primitive, everything else derives from it.
    """

    pid: int = 0
    nproc: int = 1

    # cumulative accounting (the bench multi-host row and /stats read these)
    rounds: int = 0
    seconds: float = 0.0
    payload_bytes: int = 0

    def allgather(self, payload: bytes) -> list[bytes]:
        raise NotImplementedError

    def allreduce_sum(self, arr: np.ndarray) -> np.ndarray:
        """Element-wise sum of one equal-shape int64 array per process."""
        arr = np.ascontiguousarray(arr, dtype=np.int64)
        if self.nproc == 1:
            self.rounds += 1
            return arr.copy()
        parts = self.allgather(arr.tobytes())
        out = np.zeros_like(arr)
        for raw in parts:
            out += np.frombuffer(raw, dtype=np.int64).reshape(arr.shape)
        return out

    def allgather_obj(self, obj) -> list:
        """All-gather arbitrary (trusted, in-fleet) python payloads."""
        if self.nproc == 1:
            self.rounds += 1
            return [obj]
        return [pickle.loads(raw) for raw in self.allgather(pickle.dumps(obj))]

    def agree(self, value: bytes, what: str = "value") -> bytes:
        """Assert every process holds the same ``value`` (watermarks,
        digests); returns it. Divergence raises :class:`FleetDesyncError`."""
        if self.nproc == 1:
            self.rounds += 1
            return value
        parts = self.allgather(value)
        for pid, other in enumerate(parts):
            if other != value:
                raise FleetDesyncError(
                    f"{what} diverged: p{self.pid}={value!r} p{pid}={other!r}"
                )
        return value

    def barrier(self, name: str = "sync") -> None:
        self.allgather(name.encode())

    def stats(self) -> dict:
        return {
            "nproc": self.nproc,
            "pid": self.pid,
            "rounds": self.rounds,
            "seconds": round(self.seconds, 6),
            "payload_bytes": self.payload_bytes,
        }


class LoopbackCollective(Collective):
    """Single-process fleet: every collective is the identity."""

    def __init__(self):
        self.pid = 0
        self.nproc = 1
        self.rounds = 0
        self.seconds = 0.0
        self.payload_bytes = 0

    def allgather(self, payload: bytes) -> list[bytes]:
        self.rounds += 1
        self.payload_bytes += len(payload)
        return [payload]

    def __repr__(self) -> str:
        return "LoopbackCollective()"


class FleetCollective(Collective):
    """Key-value-store collectives over ``jax.distributed``'s coordination
    client. Requires ``jax.distributed.initialize`` to have run; one
    instance per process, shared by the store, placement and coordinator
    (rounds are a single global sequence, guarded by a lock so service
    worker threads cannot interleave two collectives)."""

    def __init__(self, *, timeout_s: float = 60.0, namespace: str = "fleet"):
        import jax
        from jax._src import distributed as _dist

        client = _dist.global_state.client
        if client is None:
            raise RuntimeError(
                "FleetCollective needs jax.distributed.initialize() first"
            )
        self._client = client
        self.pid = int(jax.process_index())
        self.nproc = int(jax.process_count())
        self.timeout_s = float(timeout_s)
        self._ns = namespace
        self._round = 0
        self._lock = threading.Lock()
        self.rounds = 0
        self.seconds = 0.0
        self.payload_bytes = 0

    def _gc(self, n: int) -> None:
        # Deleting our round n-2 key at the start of round n is race-free:
        # a blocking read is a rendezvous, so finishing round n-1 implies
        # every peer *started* n-1, which implies every peer *finished* n-2
        # — and finishing n-2 means it read all n-2 keys, ours included.
        if n >= 2:
            try:
                self._client.key_value_delete(f"{self._ns}/{n - 2}/{self.pid}")
            except Exception:
                pass  # GC best-effort; stale keys only cost coordinator RAM

    def allgather(self, payload: bytes) -> list[bytes]:
        if self.nproc == 1:
            self.rounds += 1
            self.payload_bytes += len(payload)
            return [payload]
        t0 = time.perf_counter()
        with self._lock:
            n = self._round
            self._round += 1
            self._gc(n)
            self._client.key_value_set_bytes(f"{self._ns}/{n}/{self.pid}", payload)
            out: list[bytes] = []
            timeout_ms = max(1, int(self.timeout_s * 1000))
            for pid in range(self.nproc):
                if pid == self.pid:
                    out.append(payload)
                    continue
                try:
                    out.append(
                        self._client.blocking_key_value_get_bytes(
                            f"{self._ns}/{n}/{pid}", timeout_ms
                        )
                    )
                except Exception as exc:
                    raise FleetTimeout(
                        f"peer p{pid} missed round {n} within "
                        f"{self.timeout_s:.1f}s: {exc}"
                    ) from exc
            self.rounds += 1
            self.payload_bytes += sum(len(b) for b in out)
            self.seconds += time.perf_counter() - t0
        return out

    def __repr__(self) -> str:
        return f"FleetCollective(pid={self.pid}, nproc={self.nproc})"
